//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A1  pallas vs plain-jnp lowering of the AOT artifacts (is the L1
//!      kernel structure preserved through interpret-mode lowering?)
//!  A2  JIT codegen cost vs domain (why the fingerprint cache exists:
//!      first call = build+compile, later calls = cache hit)
//!  A3  definition-fingerprint cache: re-compiling a reformatted source
//!      must be a pure hash lookup
//!  A4  optimizer pass ablation: vector-backend hdiff/vadv time at each
//!      pass-manager configuration (the Fig. 3 workload, per-pass rows —
//!      temporary demotion and the fused evaluator are the headlines)
//!  A5  fused loop-nest evaluator vs materializing vector path: wall time
//!      *and* region-buffer traffic (the fused path must allocate zero
//!      per-expression-node buffers)
//!  A6  intra-call domain-sharding scaling (1/2/4/8 threads, effective
//!      thread counts, bitwise honesty gate) — lives in its own target,
//!      `benches/scaling.rs`, publishing `BENCH_scaling.json` next to
//!      this bench's `BENCH_ablation.json`
//!
//!     cargo bench --bench ablation [-- --tiny] [-- --json PATH]
//!
//! `--tiny` shrinks domains/iterations for CI smoke runs; `--json PATH`
//! additionally writes every measured row as a JSON array (the CI
//! perf-trajectory artifact, `BENCH_ablation.json`).

#[path = "harness.rs"]
mod harness;

use gt4rs::backend::kernels::ExecTier;
use gt4rs::backend::pjrt_aot::PjrtAotBackend;
use gt4rs::backend::vector::VectorBackend;
use gt4rs::backend::xlagen;
use gt4rs::backend::{Backend, RunConfig, StencilArgs};
use gt4rs::coordinator::{def_fingerprint, Coordinator};
use gt4rs::opt::{OptConfig, OptLevel, PassManager};
use gt4rs::runtime::Runtime;
use gt4rs::stdlib;
use gt4rs::storage::Storage;
use harness::*;
use std::time::Instant;

/// One measured row, serialized into the JSON artifact. Buffer counters
/// are normalized per call so rows compare across iteration counts
/// (`--tiny` vs full runs) and across benches.
struct Row {
    bench: &'static str,
    stencil: String,
    domain: String,
    config: String,
    median_ns: u128,
    pool_taken: u64,
    pool_allocated: u64,
    /// Per-call strip/block mix of the fused path's executors (zero for
    /// the materializing configurations): interpreted strips, guarded
    /// specialized strips, and blocked interior tiles — the columns that
    /// show *why* the specialized tier wins.
    strips_interpreted: u64,
    strips_guarded: u64,
    blocks_interior: u64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"bench\":\"{}\",\"stencil\":\"{}\",\"domain\":\"{}\",\"config\":\"{}\",\
             \"median_ns\":{},\"pool_taken\":{},\"pool_allocated\":{},\
             \"strips_interpreted\":{},\"strips_guarded\":{},\"blocks_interior\":{}}}",
            self.bench,
            self.stencil,
            self.domain,
            self.config,
            self.median_ns,
            self.pool_taken,
            self.pool_allocated,
            self.strips_interpreted,
            self.strips_guarded,
            self.blocks_interior
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1))
        .cloned();

    let (a4_domains, a5_domains, iters): (Vec<[usize; 3]>, Vec<[usize; 3]>, usize) = if tiny
    {
        (vec![[16, 16, 8]], vec![[16, 16, 8]], 3)
    } else {
        (
            vec![[64, 64, 32], [128, 128, 64]],
            vec![[64, 64, 32], [128, 128, 64]],
            9,
        )
    };

    let mut rows: Vec<Row> = Vec::new();
    a4_opt_pass_ablation(&a4_domains, iters, &mut rows);
    a5_fused_vs_materialized(&a5_domains, iters, &mut rows);
    if !tiny {
        if gt4rs::runtime::pjrt_available() {
            a1_pallas_vs_jnp();
            a2_jit_compile_cost();
        } else {
            println!("# A1/A2 skipped: PJRT runtime unavailable\n");
        }
        a3_fingerprint_cache();
    }

    if let Some(path) = json_path {
        let body: Vec<String> = rows.iter().map(Row::json).collect();
        let doc = format!("[\n  {}\n]\n", body.join(",\n  "));
        std::fs::write(&path, doc).expect("write bench JSON artifact");
        println!("# wrote {} rows to {path}", rows.len());
    }
}

/// Storages for a library stencil's fields over `domain`, deterministically
/// filled.
fn stencil_fields(ir: &gt4rs::StencilIr, domain: [usize; 3]) -> Vec<(String, Storage)> {
    ir.fields
        .iter()
        .map(|f| {
            let e = f.extent;
            let mut s = Storage::zeros(gt4rs::storage::StorageInfo::new(
                domain,
                [
                    ((-e.i.0) as usize, e.i.1 as usize),
                    ((-e.j.0) as usize, e.j.1 as usize),
                    ((-e.k.0) as usize, e.k.1 as usize),
                ],
            ));
            fill_storage(&mut s, 1.0);
            (f.name.clone(), s)
        })
        .collect()
}

/// A4: per-pass optimizer ablation on the vector backend.
///
/// Configurations build up the pass pipeline one pass at a time: `+demote`
/// removes the whole-field temporary traffic, and `O3 fused` additionally
/// replaces the per-expression-node materialization with the tape-based
/// fused loop nests.
fn a4_opt_pass_ablation(domains: &[[usize; 3]], iters: usize, rows: &mut Vec<Row>) {
    println!("# A4: optimizer pass ablation — vector backend, median wall time per call");
    let configs: [(&str, OptConfig); 5] = [
        ("O0 (none)", OptConfig::none()),
        ("+fold-cse", OptConfig { fold_cse: true, ..OptConfig::none() }),
        (
            "+dce+fuse",
            OptConfig { fold_cse: true, dce: true, fuse: true, ..OptConfig::none() },
        ),
        (
            "+demote (O2)",
            OptConfig {
                fold_cse: true,
                dce: true,
                fuse: true,
                demote: true,
                ..OptConfig::none()
            },
        ),
        ("O3 fused", OptConfig::level(OptLevel::O3)),
    ];
    println!("{:<12} {:>8} {:>14} {:>12}", "domain", "stencil", "config", "median");
    for domain in domains {
        let domain = *domain;
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for (name, scalars) in [("hdiff", vec![]), ("vadv", vec![("dtdz", 0.3)])] {
            let mut baseline = None;
            for (cname, config) in &configs {
                let mut ir = stdlib::compile(name).unwrap();
                PassManager::new(config).run(&mut ir);
                let be = VectorBackend::new();
                let mut fields = stencil_fields(&ir, domain);
                let mut calls = 0u64;
                let sample = bench(iters, || {
                    calls += 1;
                    let mut refs: Vec<(&str, &mut Storage)> = fields
                        .iter_mut()
                        .map(|(n, s)| (n.as_str(), s))
                        .collect();
                    be.run(&ir, &mut StencilArgs {
                        fields: &mut refs,
                        scalars: &scalars,
                        domain,
                    })
                    .unwrap();
                });
                let stats = be.take_pool_stats();
                let speedup = match baseline {
                    None => {
                        baseline = Some(sample.median);
                        "1.00x".to_string()
                    }
                    Some(base) => format!(
                        "{:.2}x",
                        base.as_secs_f64() / sample.median.as_secs_f64().max(1e-12)
                    ),
                };
                println!(
                    "{dstr:<12} {name:>8} {cname:>14} {:>12} ({speedup} vs O0)",
                    fmt_duration(sample.median)
                );
                rows.push(Row {
                    bench: "A4",
                    stencil: name.to_string(),
                    domain: dstr.clone(),
                    config: cname.to_string(),
                    median_ns: sample.median.as_nanos(),
                    pool_taken: stats.taken / calls.max(1),
                    pool_allocated: stats.allocated / calls.max(1),
                    strips_interpreted: stats.strips_interpreted / calls.max(1),
                    strips_guarded: stats.strips_guarded / calls.max(1),
                    blocks_interior: stats.blocks_interior / calls.max(1),
                });
            }
        }
    }
    println!();
}

/// A5: the tentpole comparison — the fused path's two executor tiers
/// (interpreted tape walk vs compiled kernel plans) against the
/// materializing vector path: wall time, region-buffer traffic, and the
/// strip/block mix per call. The counters tell the *why*: the specialized
/// tier turns almost all interpreted strips into blocked interior tiles
/// (per-op dispatch amortized over a whole j-tile), leaving only guarded
/// fringe strips behind.
fn a5_fused_vs_materialized(domains: &[[usize; 3]], iters: usize, rows: &mut Vec<Row>) {
    println!("# A5: fused tape tiers vs materializing evaluation — vector backend");
    println!(
        "{:<12} {:>8} {:>16} {:>12} {:>8} {:>6} {:>8} {:>8} {:>8}",
        "domain", "stencil", "config", "median", "vs O2", "bufs", "interp", "guarded", "blocks"
    );
    let configs: [(&str, OptLevel, ExecTier); 3] = [
        ("O2 materializing", OptLevel::O2, ExecTier::Interpreted),
        ("O3 interpreted", OptLevel::O3, ExecTier::Interpreted),
        ("O3 specialized", OptLevel::O3, ExecTier::Specialized),
    ];
    for domain in domains {
        let domain = *domain;
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for (name, scalars) in [("hdiff", vec![]), ("vadv", vec![("dtdz", 0.3)])] {
            let mut base = None;
            for (cname, level, tier) in &configs {
                let mut ir = stdlib::compile(name).unwrap();
                PassManager::new(&OptConfig::level(*level)).run(&mut ir);
                let be = VectorBackend::new();
                let mut fields = stencil_fields(&ir, domain);
                let cfg = RunConfig { tier: *tier, ..RunConfig::default() };
                let mut calls = 0u64;
                let sample = bench(iters, || {
                    calls += 1;
                    let mut refs: Vec<(&str, &mut Storage)> = fields
                        .iter_mut()
                        .map(|(n, s)| (n.as_str(), s))
                        .collect();
                    be.run_sharded(
                        &ir,
                        &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain },
                        &cfg,
                    )
                    .unwrap();
                });
                let stats = be.take_pool_stats();
                let calls = calls.max(1);
                let speedup = match base {
                    None => {
                        base = Some(sample.median);
                        "1.00x".to_string()
                    }
                    Some(b) => format!(
                        "{:.2}x",
                        b.as_secs_f64() / sample.median.as_secs_f64().max(1e-12)
                    ),
                };
                println!(
                    "{dstr:<12} {name:>8} {cname:>16} {:>12} {speedup:>8} {:>6} {:>8} {:>8} {:>8}",
                    fmt_duration(sample.median),
                    stats.taken / calls,
                    stats.strips_interpreted / calls,
                    stats.strips_guarded / calls,
                    stats.blocks_interior / calls
                );
                rows.push(Row {
                    bench: "A5",
                    stencil: name.to_string(),
                    domain: dstr.clone(),
                    config: cname.to_string(),
                    median_ns: sample.median.as_nanos(),
                    pool_taken: stats.taken / calls,
                    pool_allocated: stats.allocated / calls,
                    strips_interpreted: stats.strips_interpreted / calls,
                    strips_guarded: stats.strips_guarded / calls,
                    blocks_interior: stats.blocks_interior / calls,
                });
            }
        }
    }
    println!();
}

fn a1_pallas_vs_jnp() {
    println!("# A1: AOT artifact lowering variant — pallas kernels vs plain jnp");
    println!("{:<12} {:>8} {:>12} {:>12}", "domain", "stencil", "pallas", "jnp");
    let ir_h = stdlib::compile("hdiff").unwrap();
    let ir_v = stdlib::compile("vadv").unwrap();
    let rt = Runtime::cpu().unwrap();
    for domain in [[32, 32, 16], [64, 64, 32], [128, 128, 64]] {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for (name, ir, scalars) in [
            ("hdiff", &ir_h, vec![]),
            ("vadv", &ir_v, vec![("dtdz", 0.3)]),
        ] {
            let mut medians = Vec::new();
            for variant in ["pallas", "jnp"] {
                let be =
                    PjrtAotBackend::with_runtime(rt.clone()).with_variant(variant);
                let mut fields = stencil_fields(ir, domain);
                let sample = bench(9, || {
                    let mut refs: Vec<(&str, &mut Storage)> = fields
                        .iter_mut()
                        .map(|(n, s)| (n.as_str(), s))
                        .collect();
                    be.run(ir, &mut StencilArgs {
                        fields: &mut refs,
                        scalars: &scalars,
                        domain,
                    })
                    .unwrap();
                });
                medians.push(sample.median);
            }
            println!(
                "{dstr:<12} {name:>8} {:>12} {:>12}",
                fmt_duration(medians[0]),
                fmt_duration(medians[1])
            );
        }
    }
    println!();
}

fn a2_jit_compile_cost() {
    println!("# A2: xla-codegen JIT cost — first call (build+compile) vs cached call");
    println!("{:<12} {:>8} {:>14} {:>14}", "domain", "stencil", "first", "cached");
    for domain in [[16, 16, 8], [48, 48, 24], [96, 96, 32]] {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for name in ["hdiff", "vadv"] {
            let ir = stdlib::compile(name).unwrap();
            let be = xlagen::XlaBackend::new().unwrap();
            let mut fields = stencil_fields(&ir, domain);
            let scalars: Vec<(&str, f64)> =
                ir.scalars.iter().map(|s| (s.name.as_str(), 0.3)).collect();
            let mut run = |be: &xlagen::XlaBackend| {
                let t0 = Instant::now();
                let mut refs: Vec<(&str, &mut Storage)> = fields
                    .iter_mut()
                    .map(|(n, s)| (n.as_str(), s))
                    .collect();
                be.run(&ir, &mut StencilArgs {
                    fields: &mut refs,
                    scalars: &scalars,
                    domain,
                })
                .unwrap();
                t0.elapsed()
            };
            let first = run(&be);
            let cached = run(&be);
            println!(
                "{dstr:<12} {name:>8} {:>14} {:>14}",
                fmt_duration(first),
                fmt_duration(cached)
            );
        }
    }
    println!();
}

fn a3_fingerprint_cache() {
    println!("# A3: definition-fingerprint cache — reformatted source recompile cost");
    let src = stdlib::HDIFF_SRC;
    let reformatted = src.replace('\n', " \n ").replace("    ", "  ");
    let externals = std::collections::BTreeMap::new();

    let t0 = Instant::now();
    let mut coord = Coordinator::new();
    coord.compile_source(src, "hdiff", &externals).unwrap();
    let cold = t0.elapsed();

    let t1 = Instant::now();
    coord.compile_source(&reformatted, "hdiff", &externals).unwrap();
    let warm = t1.elapsed();
    let (hits, misses) = coord.cache_stats();

    let fp_a = def_fingerprint(src, "hdiff", &externals).unwrap();
    let fp_b = def_fingerprint(&reformatted, "hdiff", &externals).unwrap();
    assert_eq!(fp_a, fp_b, "reformatting changed the fingerprint!");
    println!("cold compile: {}   reformatted recompile: {}   cache hits/misses: {}/{}",
        fmt_duration(cold), fmt_duration(warm), hits, misses);
}
