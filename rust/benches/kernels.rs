//! A7: executor-tier ablation for the O3 fused evaluator — the
//! measurement behind the specialized kernel-plan tier. For hdiff and
//! vadv at `--opt-level 3` this times four configurations per call:
//!
//! * `interpreted` — the per-strip CTape walk (`ExecTier::Interpreted`),
//!   every op bounds-checked per lane row;
//! * `specialized` — pre-lowered kernel plans (`ExecTier::Specialized`,
//!   the default): dense slot tables, hoisted guards, monomorphized
//!   slice kernels over a cache-blocked j-tiled interior;
//! * `fast-math` — the specialized executor on the separately
//!   fingerprinted fast-math artifact (FMA contraction). Reported as its
//!   own column, never merged into the exact ones;
//! * `f32` — the specialized executor on the f32-retyped artifact
//!   (`OptConfig::with_dtype`), measuring what narrower storage buys at
//!   the same plan shape. Like fast-math it is a separately fingerprinted
//!   artifact and its own column.
//!
//! Honesty gates run before any timing: `specialized` must be **bitwise**
//! identical to `interpreted` on fresh inputs, the fast-math column
//! must agree within a relative tolerance (the property suite pins the
//! stronger per-point bound), and the f32 column must be bitwise
//! identical to its own f32 interpreted run, within a loose tolerance of
//! f64, and *not* bitwise equal to f64 (proof the storage is genuinely
//! narrower, not silently widened). A timing table for an executor that
//! changed the answer would be worthless.
//!
//!     cargo bench --bench kernels [-- --tiny] [-- --json PATH]
//!
//! `--tiny` shrinks the domain/iterations for CI smoke runs; `--json
//! PATH` writes every measured row as a JSON array, the
//! `BENCH_kernels.json` CI artifact published next to
//! `BENCH_ablation.json` and `BENCH_scaling.json`.

#[path = "harness.rs"]
mod harness;

use gt4rs::backend::kernels::ExecTier;
use gt4rs::backend::vector::VectorBackend;
use gt4rs::backend::{Backend, RunConfig, StencilArgs};
use gt4rs::dsl::ast::DType;
use gt4rs::opt::{OptConfig, OptLevel, PassManager};
use gt4rs::stdlib;
use gt4rs::storage::Storage;
use gt4rs::StencilIr;
use harness::*;

struct Row {
    stencil: String,
    domain: String,
    config: &'static str,
    dtype: &'static str,
    fast_math: bool,
    median_ns: u128,
    speedup_vs_interpreted: f64,
    /// Per-call executor counters (see `PoolStats`): which path did the
    /// work — per-op-guarded interpreter strips, guarded fringe strips,
    /// or guard-free blocked interiors.
    strips_interpreted: u64,
    strips_guarded: u64,
    blocks_interior: u64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"bench\":\"A7\",\"stencil\":\"{}\",\"domain\":\"{}\",\
             \"config\":\"{}\",\"dtype\":\"{}\",\"fast_math\":{},\"median_ns\":{},\
             \"speedup_vs_interpreted\":{:.4},\"strips_interpreted\":{},\
             \"strips_guarded\":{},\"blocks_interior\":{}}}",
            self.stencil,
            self.domain,
            self.config,
            self.dtype,
            self.fast_math,
            self.median_ns,
            self.speedup_vs_interpreted,
            self.strips_interpreted,
            self.strips_guarded,
            self.blocks_interior
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1))
        .cloned();

    let (domain, iters): ([usize; 3], usize) =
        if tiny { ([16, 16, 8], 3) } else { ([128, 128, 64], 9) };

    let mut rows: Vec<Row> = Vec::new();
    a7_tiers(domain, iters, &mut rows);

    if let Some(path) = json_path {
        let body: Vec<String> = rows.iter().map(Row::json).collect();
        let doc = format!("[\n  {}\n]\n", body.join(",\n  "));
        std::fs::write(&path, doc).expect("write kernels JSON artifact");
        println!("# wrote {} rows to {path}", rows.len());
    }
}

/// Compile a library stencil at O3, optionally as the fast-math or
/// dtype-retyped artifact (each a distinct fingerprint — relaxed,
/// narrowed and exact IRs never share a cache entry).
fn compiled(name: &str, fast_math: bool, dtype: Option<DType>) -> StencilIr {
    let mut ir = stdlib::compile(name).unwrap();
    let config =
        OptConfig::level(OptLevel::O3).with_fast_math(fast_math).with_dtype(dtype);
    PassManager::new(&config).run(&mut ir);
    ir
}

/// Fresh deterministically-filled storages for `ir` over `domain`,
/// allocated at each field's declared dtype (the fill goes through the
/// f64 facade, so f32 storages hold the rounded values).
fn fresh_fields(ir: &StencilIr, domain: [usize; 3]) -> Vec<(String, Storage)> {
    ir.fields
        .iter()
        .enumerate()
        .map(|(ix, f)| {
            let e = f.extent;
            let mut s = Storage::zeros(
                gt4rs::storage::StorageInfo::new(
                    domain,
                    [
                        ((-e.i.0) as usize, e.i.1 as usize),
                        ((-e.j.0) as usize, e.j.1 as usize),
                        ((-e.k.0) as usize, e.k.1 as usize),
                    ],
                )
                .with_dtype(f.dtype),
            );
            fill_storage(&mut s, 1.0 + ix as f64 * 0.5);
            (f.name.clone(), s)
        })
        .collect()
}

/// Run once on fresh inputs under `tier`, returning every field's
/// domain sum — the honesty fingerprint the other tiers must reproduce
/// (bitwise for exact tiers, tolerance-bounded for fast-math).
fn run_once_sums(
    be: &VectorBackend,
    ir: &StencilIr,
    domain: [usize; 3],
    scalars: &[(&str, f64)],
    tier: ExecTier,
) -> Vec<f64> {
    let mut fields = fresh_fields(ir, domain);
    {
        let mut refs: Vec<(&str, &mut Storage)> =
            fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
        be.run_sharded(
            ir,
            &mut StencilArgs { fields: &mut refs, scalars, domain },
            &RunConfig { tier, ..RunConfig::default() },
        )
        .unwrap();
    }
    fields.iter().map(|(_, s)| s.domain_sum()).collect()
}

fn a7_tiers(domain: [usize; 3], iters: usize, rows: &mut Vec<Row>) {
    let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
    println!("# A7: O3 executor tiers — interpreted tape walk vs specialized kernel plans");
    println!(
        "{:<12} {:>8} {:>12} {:>12} {:>10} {:>8} {:>8} {:>8}",
        "domain", "stencil", "config", "median", "vs interp", "interp", "guarded", "blocks"
    );
    for (name, scalars) in [("hdiff", vec![]), ("vadv", vec![("dtdz", 0.3)])] {
        let exact = compiled(name, false, None);
        let relaxed = compiled(name, true, None);
        let narrow = compiled(name, false, Some(DType::F32));
        let be = VectorBackend::new();
        // Honesty gates on fresh inputs before a single timed iteration.
        let interp = run_once_sums(&be, &exact, domain, &scalars, ExecTier::Interpreted);
        let spec = run_once_sums(&be, &exact, domain, &scalars, ExecTier::Specialized);
        for (a, b) in interp.iter().zip(&spec) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name}: specialized result diverged from interpreted"
            );
        }
        let fm = run_once_sums(&be, &relaxed, domain, &scalars, ExecTier::Specialized);
        for (a, b) in interp.iter().zip(&fm) {
            assert!(
                (a - b).abs() <= 1e-9 * (1.0 + a.abs()),
                "{name}: fast-math sum out of tolerance (exact {a}, fast-math {b})"
            );
        }
        // f32 gates: the specialized f32 executor must be bitwise
        // identical to the f32 interpreted walk, close to f64 (loose
        // norm — roundoff accumulates over the domain sum), and not
        // bitwise equal to f64 (the storage really is narrower).
        let n32i = run_once_sums(&be, &narrow, domain, &scalars, ExecTier::Interpreted);
        let n32 = run_once_sums(&be, &narrow, domain, &scalars, ExecTier::Specialized);
        for (a, b) in n32i.iter().zip(&n32) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{name}: f32 specialized result diverged from f32 interpreted"
            );
        }
        for (a, b) in interp.iter().zip(&n32) {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs()),
                "{name}: f32 sum out of tolerance (f64 {a}, f32 {b})"
            );
        }
        assert!(
            interp.iter().zip(&n32).any(|(a, b)| a.to_bits() != b.to_bits()),
            "{name}: f32 sums bitwise-matched f64 — storage silently widened"
        );
        let _ = be.take_pool_stats();
        // interpreted is measured first so every later row's speedup is
        // computed against a real baseline (never fabricated).
        let configs: [(&'static str, &StencilIr, ExecTier, bool, &'static str); 4] = [
            ("interpreted", &exact, ExecTier::Interpreted, false, "f64"),
            ("specialized", &exact, ExecTier::Specialized, false, "f64"),
            ("fast-math", &relaxed, ExecTier::Specialized, true, "f64"),
            ("f32", &narrow, ExecTier::Specialized, false, "f32"),
        ];
        let mut interp_median: Option<f64> = None;
        for (label, ir, tier, fast_math, dtype) in configs {
            let mut fields = fresh_fields(ir, domain);
            let mut calls = 0u64;
            let sample = bench(iters, || {
                calls += 1;
                let mut refs: Vec<(&str, &mut Storage)> =
                    fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
                be.run_sharded(
                    ir,
                    &mut StencilArgs { fields: &mut refs, scalars: &scalars, domain },
                    &RunConfig { tier, ..RunConfig::default() },
                )
                .unwrap();
            });
            let stats = be.take_pool_stats();
            let calls = calls.max(1);
            if label == "interpreted" {
                interp_median = Some(sample.median.as_secs_f64());
            }
            let speedup = interp_median.expect("interpreted measured first")
                / sample.median.as_secs_f64().max(1e-12);
            println!(
                "{dstr:<12} {name:>8} {label:>12} {:>12} {speedup:>9.2}x {:>8} {:>8} {:>8}",
                fmt_duration(sample.median),
                stats.strips_interpreted / calls,
                stats.strips_guarded / calls,
                stats.blocks_interior / calls,
            );
            rows.push(Row {
                stencil: name.to_string(),
                domain: dstr.clone(),
                config: label,
                dtype,
                fast_math,
                median_ns: sample.median.as_nanos(),
                speedup_vs_interpreted: speedup,
                strips_interpreted: stats.strips_interpreted / calls,
                strips_guarded: stats.strips_guarded / calls,
                blocks_interior: stats.blocks_interior / calls,
            });
        }
    }
    println!();
}
