//! FIG3-OVH: the constant per-call overhead of run-time storage checks
//! (the gap between the paper's solid and dashed lines at small domains,
//! §3.1: "a noticeable (≈1 ms) overhead ... caused by various checks
//! performed at run-time on the memory layout and data type of the
//! storage arguments").
//!
//!     cargo bench --bench overhead

#[path = "harness.rs"]
mod harness;

use gt4rs::coordinator::Coordinator;
use gt4rs::storage::Storage;
use harness::*;

fn main() {
    println!("# FIG3-OVH run-time checks overhead (solid vs dashed, small domains)");
    println!("# `checks` is the coordinator's directly-measured validation time");
    println!("# (the paper's is ~1 ms because its checks run in the Python");
    println!("# interpreter; ours are compiled — the *shape* to verify is that");
    println!("# the cost is constant in domain size and only matters where the");
    println!("# execute time is comparably small).");
    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>10}",
        "domain", "backend", "execute", "checks", "ratio"
    );

    for domain in [[8, 8, 4], [16, 16, 8], [32, 32, 16], [64, 64, 32]] {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for be in ["vector", "xla"] {
            let mut coord = Coordinator::new();
            let fp = coord.compile_library("hdiff").unwrap();
            let mut in_phi = coord.alloc_field(fp, "in_phi", domain).unwrap();
            let mut coeff = coord.alloc_field(fp, "coeff", domain).unwrap();
            let mut out = coord.alloc_field(fp, "out_phi", domain).unwrap();
            fill_storage(&mut in_phi, 1.0);
            coeff.fill(0.025);

            bench(50, || {
                let mut refs: Vec<(&str, &mut Storage)> = vec![
                    ("in_phi", &mut in_phi),
                    ("coeff", &mut coeff),
                    ("out_phi", &mut out),
                ];
                coord.run(fp, be, &mut refs, &[], domain).unwrap();
            });
            let t = coord.metrics.get("hdiff", be).unwrap();
            let calls = t.calls as u32;
            let (exec, checks) = (t.execute / calls, t.checks / calls);
            println!(
                "{dstr:<12} {be:>10} {:>12} {:>12} {:>9.4}%",
                fmt_duration(exec),
                fmt_duration(checks),
                100.0 * checks.as_secs_f64() / exec.as_secs_f64().max(1e-12),
            );
        }
    }
    println!("# shape check: `checks` column constant across domains; the ratio");
    println!("# column decays as the domain grows (paper Fig. 3 solid vs dashed).");
}
