//! FIG3-OVH: the constant per-call overhead of run-time storage checks
//! (the gap between the paper's solid and dashed lines at small domains,
//! §3.1: "a noticeable (≈1 ms) overhead ... caused by various checks
//! performed at run-time on the memory layout and data type of the
//! storage arguments").
//!
//! Two configurations per (domain, backend) cell:
//! * `per-call` — re-bind on every call, so each run pays the full
//!   layout/halo/dtype validation (the paper's solid line);
//! * `bound` — the stencil handle API: validation happened once at bind
//!   time, each call only re-checks shapes (the dashed line *without*
//!   disabling checks).
//!
//!     cargo bench --bench overhead

#[path = "harness.rs"]
mod harness;

use gt4rs::coordinator::Coordinator;
use harness::*;

fn main() {
    println!("# FIG3-OVH run-time checks overhead (solid vs dashed, small domains)");
    println!("# `per-call checks` = full validation on every call (re-bind per call);");
    println!("# `bound checks`    = the BoundInvocation shape re-check. The paper's");
    println!("# overhead is ~1 ms because its checks run in the Python interpreter;");
    println!("# ours are compiled — the *shape* to verify is that the cost is");
    println!("# constant in domain size, and that binding once removes most of it.");
    println!(
        "{:<12} {:>10} {:>12} {:>16} {:>14} {:>10}",
        "domain", "backend", "execute", "per-call checks", "bound checks", "ratio"
    );

    for domain in [[8, 8, 4], [16, 16, 8], [32, 32, 16], [64, 64, 32]] {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for be in ["vector", "xla"] {
            let mut coord = Coordinator::new();
            let fp = coord.compile_library("hdiff").unwrap();
            let stencil = match coord.stencil_for(fp, be) {
                Ok(s) => s,
                Err(_) => {
                    println!(
                        "{dstr:<12} {be:>10} {:>12} {:>16} {:>14} {:>10}",
                        "n/a", "n/a", "n/a", "n/a"
                    );
                    continue;
                }
            };
            let mut in_phi = stencil.alloc_field("in_phi", domain).unwrap();
            let mut coeff = stencil.alloc_field("coeff", domain).unwrap();
            let mut out = stencil.alloc_field("out_phi", domain).unwrap();
            fill_storage(&mut in_phi, 1.0);
            coeff.fill(0.025);

            // Per-call path: a fresh bind before every run, so each call
            // pays the full validation — the cost profile of the old
            // slice-based entry points, expressed through the handle API.
            bench(50, || {
                let mut call = stencil
                    .bind()
                    .field("in_phi", &in_phi)
                    .field("coeff", &coeff)
                    .field("out_phi", &out)
                    .domain(domain)
                    .finish()
                    .unwrap();
                call.run(&mut [&mut in_phi, &mut coeff, &mut out]).unwrap();
            });
            let legacy = coord.metrics.get("hdiff", be).unwrap();

            // Handle path: bind once, run many (fresh coordinator so the
            // metrics split cleanly). The first call absorbs the one-time
            // bind validation into its stats; measure from the snapshot
            // after it so the column is the pure per-call shape re-check.
            let mut coord2 = Coordinator::new();
            let fp2 = coord2.compile_library("hdiff").unwrap();
            let stencil2 = coord2.stencil_for(fp2, be).unwrap();
            let mut inv = stencil2
                .bind()
                .field("in_phi", &in_phi)
                .field("coeff", &coeff)
                .field("out_phi", &out)
                .domain(domain)
                .finish()
                .unwrap();
            inv.run(&mut [&mut in_phi, &mut coeff, &mut out]).unwrap();
            let bound0 = coord2.metrics.get("hdiff", be).unwrap();
            bench(50, || {
                inv.run(&mut [&mut in_phi, &mut coeff, &mut out]).unwrap();
            });
            let bound = coord2.metrics.get("hdiff", be).unwrap();

            let calls = legacy.calls as u32;
            let (exec, checks) = (legacy.execute / calls, legacy.checks / calls);
            let bound_checks =
                (bound.checks - bound0.checks) / (bound.calls - bound0.calls) as u32;
            println!(
                "{dstr:<12} {be:>10} {:>12} {:>16} {:>14} {:>9.4}%",
                fmt_duration(exec),
                fmt_duration(checks),
                fmt_duration(bound_checks),
                100.0 * checks.as_secs_f64() / exec.as_secs_f64().max(1e-12),
            );
        }
    }
    println!("# shape check: `per-call checks` constant across domains; `bound");
    println!("# checks` at least an order of magnitude below it; the ratio column");
    println!("# decays as the domain grows (paper Fig. 3 solid vs dashed).");
}
