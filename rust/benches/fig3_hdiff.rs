//! FIG3-L: horizontal diffusion execution time per backend vs domain size
//! (paper Figure 3, left panel).
//!
//! Solid lines in the paper = total call time including run-time storage
//! checks; dashed lines = raw execution. Both are reported here (`total`
//! vs `exec`). With the stencil handle API the full validation runs once
//! at bind time; the per-call `checks` is the shape re-check — the
//! `overhead` bench isolates both.
//!
//!     cargo bench --bench fig3_hdiff

#[path = "harness.rs"]
mod harness;

use gt4rs::baseline;
use gt4rs::coordinator::Coordinator;
use harness::*;

fn main() {
    let mut coord = Coordinator::new();
    let fp = coord.compile_library("hdiff").expect("compile hdiff");

    println!("# FIG3-L horizontal diffusion — median wall/call (paper Fig. 3 left)");
    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>10}",
        "domain", "backend", "exec", "total", "iters"
    );

    for domain in FIG3_DOMAINS {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for be in ["debug", "vector", "xla", "pjrt-aot"] {
            let stencil = match coord.stencil_for(fp, be) {
                Ok(s) => s,
                Err(_) => {
                    println!("{dstr:<12} {be:>10} {:>12} {:>12} {:>10}", "n/a", "n/a", 0);
                    continue;
                }
            };
            let mut in_phi = stencil.alloc_field("in_phi", domain).unwrap();
            let mut coeff = stencil.alloc_field("coeff", domain).unwrap();
            let mut out = stencil.alloc_field("out_phi", domain).unwrap();
            fill_storage(&mut in_phi, 1.0);
            coeff.fill(0.025);

            // Bind once (full validation), then an availability probe that
            // doubles as the JIT warmup.
            let mut inv = stencil
                .bind()
                .field("in_phi", &in_phi)
                .field("coeff", &coeff)
                .field("out_phi", &out)
                .domain(domain)
                .finish()
                .unwrap();
            let probe = inv.run(&mut [&mut in_phi, &mut coeff, &mut out]);
            if probe.is_err() {
                println!("{dstr:<12} {be:>10} {:>12} {:>12} {:>10}", "n/a", "n/a", 0);
                continue;
            }

            let iters = if be == "debug" && domain[0] >= 96 { 3 } else { 9 };
            let mut last_checks = std::time::Duration::ZERO;
            let sample = bench(iters, || {
                let stats = inv.run(&mut [&mut in_phi, &mut coeff, &mut out]).unwrap();
                last_checks = stats.checks;
            });
            println!(
                "{dstr:<12} {be:>10} {:>12} {:>12} {iters:>10}",
                fmt_duration(sample.median.saturating_sub(last_checks)),
                fmt_duration(sample.median),
            );
        }

        // hand-written native reference (the paper's "near-native C++")
        {
            let mut in_phi = coord.alloc_field(fp, "in_phi", domain).unwrap();
            let mut coeff = coord.alloc_field(fp, "coeff", domain).unwrap();
            let mut out = coord.alloc_field(fp, "out_phi", domain).unwrap();
            fill_storage(&mut in_phi, 1.0);
            coeff.fill(0.025);
            let sample = bench(9, || {
                baseline::hdiff_native(&in_phi, &coeff, &mut out, domain);
            });
            println!(
                "{dstr:<12} {:>10} {:>12} {:>12} {:>10}",
                "native",
                fmt_duration(sample.median),
                fmt_duration(sample.median),
                9
            );
        }
    }
    println!("# shape check (paper): compiled backends >= 10x faster than the");
    println!("# interpreter tiers; gap grows with domain size; constant small-");
    println!("# domain overhead on the total column.");
}
