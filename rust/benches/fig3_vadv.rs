//! FIG3-R: implicit vertical advection execution time per backend vs
//! domain size (paper Figure 3, right panel).
//!
//!     cargo bench --bench fig3_vadv

#[path = "harness.rs"]
mod harness;

use gt4rs::baseline;
use gt4rs::coordinator::Coordinator;
use harness::*;

fn main() {
    let mut coord = Coordinator::new();
    let fp = coord.compile_library("vadv").expect("compile vadv");
    let dtdz = 0.3;

    println!("# FIG3-R vertical advection — median wall/call (paper Fig. 3 right)");
    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>10}",
        "domain", "backend", "exec", "total", "iters"
    );

    for domain in FIG3_DOMAINS {
        let dstr = format!("{}x{}x{}", domain[0], domain[1], domain[2]);
        for be in ["debug", "vector", "xla", "pjrt-aot"] {
            // The xla backend unrolls K in the graph: JIT compile cost grows
            // superlinearly with nk. Cap it (the pjrt-aot tier is the
            // compiled path at scale); see DESIGN.md §Perf.
            if be == "xla" && domain[2] > 32 {
                println!(
                    "{dstr:<12} {be:>10} {:>12} {:>12} {:>10}",
                    "(skipped)", "(compile)", 0
                );
                continue;
            }
            let stencil = match coord.stencil_for(fp, be) {
                Ok(s) => s,
                Err(_) => {
                    println!("{dstr:<12} {be:>10} {:>12} {:>12} {:>10}", "n/a", "n/a", 0);
                    continue;
                }
            };
            let mut phi = stencil.alloc_field("phi", domain).unwrap();
            let mut w = stencil.alloc_field("w", domain).unwrap();
            fill_storage(&mut phi, 2.0);
            fill_storage(&mut w, 3.0);

            let mut inv = stencil
                .bind()
                .field("phi", &phi)
                .field("w", &w)
                .scalar("dtdz", dtdz)
                .domain(domain)
                .finish()
                .unwrap();
            let probe = inv.run(&mut [&mut phi, &mut w]);
            if probe.is_err() {
                println!("{dstr:<12} {be:>10} {:>12} {:>12} {:>10}", "n/a", "n/a", 0);
                continue;
            }

            let iters = if be == "debug" && domain[0] >= 96 { 3 } else { 9 };
            let mut last_checks = std::time::Duration::ZERO;
            let sample = bench(iters, || {
                let stats = inv.run(&mut [&mut phi, &mut w]).unwrap();
                last_checks = stats.checks;
            });
            println!(
                "{dstr:<12} {be:>10} {:>12} {:>12} {iters:>10}",
                fmt_duration(sample.median.saturating_sub(last_checks)),
                fmt_duration(sample.median),
            );
        }

        // hand-written native Thomas solver
        {
            let mut phi = coord.alloc_field(fp, "phi", domain).unwrap();
            let w = {
                let mut w = coord.alloc_field(fp, "w", domain).unwrap();
                fill_storage(&mut w, 3.0);
                w
            };
            fill_storage(&mut phi, 2.0);
            let sample = bench(9, || {
                baseline::vadv_native(&mut phi, &w, dtdz, domain);
            });
            println!(
                "{dstr:<12} {:>10} {:>12} {:>12} {:>10}",
                "native",
                fmt_duration(sample.median),
                fmt_duration(sample.median),
                9
            );
        }
    }
}
