//! Minimal benchmark harness shared by the `cargo bench` targets.
//!
//! (criterion is not in the offline vendored crate set, so the harness is
//! in-repo: warmup + N timed iterations, reporting min/median/mean — the
//! same methodology, smaller machinery. Bench targets set
//! `harness = false`.)

use std::time::{Duration, Instant};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

/// Run `f` once as warmup (compile/caches), then `iters` timed times.
pub fn bench(iters: usize, mut f: impl FnMut()) -> Sample {
    f(); // warmup: JIT compile, cache fill — excluded, like criterion's warmup
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    Sample { min, median, mean }
}

/// Pick an iteration count so slow cases don't stall the suite.
pub fn auto_iters(probe: impl FnOnce()) -> usize {
    let t0 = Instant::now();
    probe();
    let dt = t0.elapsed();
    if dt > Duration::from_millis(500) {
        3
    } else if dt > Duration::from_millis(50) {
        7
    } else {
        15
    }
}

pub fn fmt_duration(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}µs")
    } else if us < 1_000_000.0 {
        format!("{:.2}ms", us / 1000.0)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

/// Deterministic smooth field filler shared by the benches.
pub fn fill_storage(s: &mut gt4rs::storage::Storage, seed: f64) {
    let [ni, nj, nk] = s.info.shape;
    let h = s.info.halo;
    for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
        for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
            for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                let v = ((i as f64) * 0.21 + seed).sin() * ((j as f64) * 0.17).cos()
                    + 0.05 * (k as f64);
                s.set(i, j, k, v);
            }
        }
    }
}

/// The Figure-3 domain sweep (kept in sync with python/compile/aot.py).
pub const FIG3_DOMAINS: [[usize; 3]; 6] = [
    [16, 16, 8],
    [32, 32, 16],
    [48, 48, 24],
    [64, 64, 32],
    [96, 96, 48],
    [128, 128, 64],
];
