//! A8: `repro serve` load bench — requests/sec through the daemon for
//! 1/2/4/8 concurrent clients hammering one small-domain stencil (the
//! configuration where the coalescer folds same-fingerprint runs into
//! shared dispatch windows).
//!
//! Before any timing, the wire path is checked **bitwise** against
//! serial in-process execution at O0 and O2 — a throughput number for a
//! service that changed the answer would be worthless (same honesty gate
//! discipline as the scaling/ablation benches).
//!
//!     cargo bench --bench serve [-- --tiny] [-- --json PATH]
//!
//! `--tiny` shrinks the request count for CI smoke runs; `--json PATH`
//! writes every measured row as a JSON array, the `BENCH_serve.json` CI
//! artifact.

#[path = "harness.rs"]
#[allow(dead_code)] // only `fmt_duration` is used here
mod harness;

use gt4rs::jsonw::{self, Value};
use gt4rs::serve::protocol::hex64;
use gt4rs::serve::{ServeConfig, Server};
use gt4rs::storage::{synthetic_fill, Storage};
use gt4rs::{Coordinator, ExecOptions, OptLevel};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

const DOMAIN: [usize; 3] = [16, 16, 8];
const DOMAIN_JSON: &str = "[16,16,8]";

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to serve daemon");
        let writer = stream.try_clone().unwrap();
        Client { reader: BufReader::new(stream), writer }
    }

    fn request(&mut self, line: &str) -> Value {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        jsonw::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response `{resp}`: {e}"))
    }
}

fn ok(v: &Value) -> bool {
    v.get("ok").and_then(Value::as_bool) == Some(true)
}

struct Row {
    clients: usize,
    requests: usize,
    wall_ns: u128,
    requests_per_sec: f64,
    coalesced_runs: u64,
    backpressure: u64,
}

impl Row {
    fn json(&self) -> String {
        format!(
            "{{\"bench\":\"A8\",\"domain\":\"16x16x8\",\"clients\":{},\"requests\":{},\
             \"wall_ns\":{},\"requests_per_sec\":{:.1},\"coalesced_runs\":{},\
             \"backpressure\":{}}}",
            self.clients,
            self.requests,
            self.wall_ns,
            self.requests_per_sec,
            self.coalesced_runs,
            self.backpressure
        )
    }
}

/// Serial in-process digests: same library stencil, same deterministic
/// fill and default scalars the daemon uses for `bind`.
fn reference_digests(level: OptLevel) -> Vec<(String, String, String)> {
    let mut coord = Coordinator::new();
    coord.set_exec_options(ExecOptions::new().with_opt_level(level));
    let stencil = coord.stencil_library("hdiff", "vector").unwrap();
    let mut fields: Vec<(String, Storage)> = Vec::new();
    for (idx, f) in stencil.ir().fields.iter().enumerate() {
        let mut s = stencil.alloc_field(&f.name, DOMAIN).unwrap();
        synthetic_fill(&mut s, idx as f64);
        fields.push((f.name.clone(), s));
    }
    let scalars: Vec<(String, f64)> =
        stencil.ir().scalars.iter().map(|s| (s.name.clone(), 0.1)).collect();
    let mut inv = stencil
        .bind()
        .domain(DOMAIN)
        .fields(&fields)
        .scalars(&scalars)
        .finish()
        .unwrap();
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs).unwrap();
    fields
        .iter()
        .map(|(n, s)| {
            (n.clone(), hex64(s.domain_sum().to_bits()), hex64(s.domain_hash()))
        })
        .collect()
}

/// One wire round-trip (bind + run) at `level`, returning its digests.
fn wire_digests(addr: SocketAddr, level: OptLevel) -> Vec<(String, String, String)> {
    let mut client = Client::connect(addr);
    let bind = client.request(&format!(
        r#"{{"op":"bind","tenant":"gate","stencil":"hdiff","domain":{DOMAIN_JSON},"options":{{"opt_level":"{level}"}}}}"#
    ));
    assert!(ok(&bind), "{bind:?}");
    let lease = bind.get("lease").unwrap().as_u64().unwrap();
    let run = client.request(&format!(r#"{{"op":"run","tenant":"gate","lease":{lease}}}"#));
    assert!(ok(&run), "{run:?}");
    run.get("fields")
        .and_then(Value::as_arr)
        .unwrap()
        .iter()
        .map(|f| {
            (
                f.get("name").unwrap().as_str().unwrap().to_string(),
                f.get("sum_bits").unwrap().as_str().unwrap().to_string(),
                f.get("hash").unwrap().as_str().unwrap().to_string(),
            )
        })
        .collect()
}

/// A counter value from the `/metrics` text body (0 if absent).
fn metric(text: &str, name: &str) -> u64 {
    text.lines()
        .find_map(|l| l.strip_prefix(name).and_then(|rest| rest.trim().parse().ok()))
        .unwrap_or(0)
}

fn metrics_text(addr: SocketAddr) -> String {
    let mut client = Client::connect(addr);
    let m = client.request(r#"{"op":"metrics"}"#);
    m.get("text").unwrap().as_str().unwrap().to_string()
}

/// Bind one lease per client up front (off the clock), then fire
/// `requests_per_client` runs from each client concurrently.
fn measure(addr: SocketAddr, clients: usize, requests_per_client: usize) -> (Duration, usize) {
    let leases: Vec<u64> = (0..clients)
        .map(|_| {
            let mut c = Client::connect(addr);
            let bind = c.request(&format!(
                r#"{{"op":"bind","tenant":"bench","stencil":"hdiff","domain":{DOMAIN_JSON}}}"#
            ));
            assert!(ok(&bind), "{bind:?}");
            bind.get("lease").unwrap().as_u64().unwrap()
        })
        .collect();
    let t0 = Instant::now();
    let handles: Vec<_> = leases
        .into_iter()
        .map(|lease| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                for _ in 0..requests_per_client {
                    let run = c.request(&format!(
                        r#"{{"op":"run","tenant":"bench","lease":{lease}}}"#
                    ));
                    assert!(ok(&run), "{run:?}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (t0.elapsed(), clients * requests_per_client)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1))
        .cloned();
    let requests_per_client = if tiny { 10 } else { 100 };

    let mut server = Server::spawn(ServeConfig::default()).expect("spawn serve daemon");
    let addr = server.addr();

    // Honesty gate before any timing: wire == serial in-process, bitwise.
    for level in [OptLevel::O0, OptLevel::O2] {
        assert_eq!(
            wire_digests(addr, level),
            reference_digests(level),
            "wire run diverged from serial in-process at O{level}"
        );
    }
    println!("# A8: serve throughput — hdiff 16x16x8, bitwise gate passed (O0, O2)");
    println!("{:<8} {:>10} {:>12} {:>14} {:>12} {:>10}", "clients", "requests", "wall", "req/s", "coalesced", "shed");

    let mut rows: Vec<Row> = Vec::new();
    for clients in [1usize, 2, 4, 8] {
        let before = metrics_text(addr);
        let (wall, requests) = measure(addr, clients, requests_per_client);
        let after = metrics_text(addr);
        let coalesced = metric(&after, "serve_coalesced_runs_total")
            - metric(&before, "serve_coalesced_runs_total");
        let backpressure = metric(&after, "serve_backpressure_total")
            - metric(&before, "serve_backpressure_total");
        let rps = requests as f64 / wall.as_secs_f64().max(1e-9);
        println!(
            "{clients:<8} {requests:>10} {:>12} {rps:>14.1} {coalesced:>12} {backpressure:>10}",
            harness::fmt_duration(wall)
        );
        rows.push(Row {
            clients,
            requests,
            wall_ns: wall.as_nanos(),
            requests_per_sec: rps,
            coalesced_runs: coalesced,
            backpressure,
        });
    }

    server.shutdown();

    if let Some(path) = json_path {
        let body: Vec<String> = rows.iter().map(Row::json).collect();
        let doc = format!("[\n  {}\n]\n", body.join(",\n  "));
        std::fs::write(&path, doc).expect("write serve JSON artifact");
        println!("# wrote {} rows to {path}", rows.len());
    }
}
