//! Property tests for the first-class stencil handle API (the
//! `StencilObject` analog): concurrent dispatch of one shared handle must
//! be bitwise identical to serial execution on the interpreting backends
//! at every opt level, bind-once/run-many semantics must catch stale
//! storages, and a bound invocation's repeat calls must pay at least an
//! order of magnitude less validation time than the first (full) one.

use gt4rs::coordinator::{BoundInvocation, Coordinator, Stencil};
use gt4rs::opt::OptLevel;
use gt4rs::storage::Storage;
use gt4rs::Sharding;

const LEVELS: [OptLevel; 4] = [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];

struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
    fn f64(&mut self) -> f64 {
        (self.next() as f64) / (u32::MAX as f64) - 0.5
    }
}

/// Deterministic per-seed storages for every field of `handle`, halos
/// included.
fn seeded_fields(
    handle: &Stencil,
    domain: [usize; 3],
    seed: u64,
) -> Vec<(String, Storage)> {
    let mut rng = Rng(seed.wrapping_mul(0x9e3779b97f4a7c15).wrapping_add(1));
    handle
        .ir()
        .fields
        .iter()
        .map(|f| {
            let mut s = handle.alloc_field(&f.name, domain).unwrap();
            let [ni, nj, nk] = domain;
            let h = s.info.halo;
            for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                    for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                        s.set(i, j, k, rng.f64());
                    }
                }
            }
            (f.name.clone(), s)
        })
        .collect()
}

fn bind(
    handle: &Stencil,
    fields: &[(String, Storage)],
    scalars: &[(&str, f64)],
    domain: [usize; 3],
) -> BoundInvocation {
    handle.bind().domain(domain).fields(fields).scalars(scalars).finish().unwrap()
}

/// Bind seed-dependent inputs to `handle` and run `iters` times, feeding
/// the output back into the input so every iteration depends on the last
/// (the result is sensitive to any cross-thread interference in the
/// backend's shared state).
fn run_workload(
    handle: &Stencil,
    domain: [usize; 3],
    seed: u64,
    iters: usize,
) -> Vec<(String, Storage)> {
    let scalars: Vec<(&str, f64)> = handle
        .ir()
        .scalars
        .iter()
        .map(|s| (s.name.as_str(), 0.3))
        .collect();
    let mut fields = seeded_fields(handle, domain, seed);
    let mut inv = bind(handle, &fields, &scalars, domain);
    for it in 0..iters {
        {
            let mut refs: Vec<&mut Storage> =
                fields.iter_mut().map(|(_, s)| s).collect();
            inv.run(&mut refs).unwrap();
        }
        // Copy the last field's domain into the first input so successive
        // iterations are data-dependent (any cross-thread corruption of
        // the backend's shared state would compound and show up).
        if it + 1 < iters {
            let last_vals = fields.last().unwrap().1.clone();
            let (_, inp) = fields.first_mut().unwrap();
            for i in 0..domain[0] as i64 {
                for j in 0..domain[1] as i64 {
                    for k in 0..domain[2] as i64 {
                        inp.set(i, j, k, last_vals.get(i, j, k));
                    }
                }
            }
        }
    }
    fields
}

fn assert_bitwise_equal(
    a: &[(String, Storage)],
    b: &[(String, Storage)],
    context: &str,
) {
    for ((n, x), (_, y)) in a.iter().zip(b) {
        assert_eq!(
            x.max_abs_diff(y),
            0.0,
            "{context}: field `{n}` differs between serial and concurrent runs"
        );
    }
}

/// (a) of the acceptance criteria: N threads hammering one cloned handle
/// produce results bitwise identical to running the same workloads
/// serially — on debug and vector, at every opt level (the vector legs at
/// O2/O3 exercise the materializing and fused evaluators' shared caches
/// and buffer pools).
#[test]
fn concurrent_dispatch_bitwise_equals_serial() {
    const THREADS: u64 = 4;
    let domain = [9, 8, 5];
    for level in LEVELS {
        for be in ["debug", "vector"] {
            for stencil_name in ["hdiff", "vadv"] {
                let mut coord = Coordinator::with_opt_level(level);
                // The CI thread-matrix reaches this suite here: any plan
                // in REPRO_THREADS shards every call of both the serial
                // and the concurrent legs (the comparison stays valid —
                // sharding is bitwise-invisible by contract).
                coord.set_sharding(Sharding::from_env());
                let handle = coord.stencil_library(stencil_name, be).unwrap();

                let serial: Vec<_> = (0..THREADS)
                    .map(|t| run_workload(&handle, domain, t, 3))
                    .collect();

                let concurrent: Vec<_> = std::thread::scope(|s| {
                    let joins: Vec<_> = (0..THREADS)
                        .map(|t| {
                            let h = handle.clone();
                            s.spawn(move || run_workload(&h, domain, t, 3))
                        })
                        .collect();
                    joins.into_iter().map(|j| j.join().unwrap()).collect()
                });

                for (t, (a, b)) in serial.iter().zip(&concurrent).enumerate() {
                    assert_bitwise_equal(
                        a,
                        b,
                        &format!("{stencil_name} O{level} {be} thread {t}"),
                    );
                }
            }
        }
    }
}

/// Outer concurrent handle dispatch composed with *inner* intra-call
/// domain sharding: 4 threads hammer one cloned handle whose every call
/// additionally fans out over 2 i-slabs (threads × slabs), on both the
/// materializing (O2) and fused (O3) vector paths. Results must be
/// bitwise identical to the serial, unsharded runs — the two parallel
/// layers must compose without contention or cross-talk (each sharded
/// call checks its own worker pool and buffer pools out of the shared
/// backend).
#[test]
fn outer_dispatch_composes_with_inner_sharding() {
    const THREADS: u64 = 4;
    let domain = [14, 9, 5];
    for level in [OptLevel::O2, OptLevel::O3] {
        for stencil_name in ["hdiff", "vadv"] {
            let mut coord = Coordinator::with_opt_level(level);
            let handle = coord.stencil_library(stencil_name, "vector").unwrap();

            // Serial reference: sharding off, one thread at a time.
            let serial: Vec<_> = (0..THREADS)
                .map(|t| run_workload(&handle, domain, t, 3))
                .collect();

            // Concurrent + sharded: every clone's calls split into 2
            // slabs on the backend's checked-out worker pools.
            let mut sharded_handle = handle.clone();
            sharded_handle.set_sharding(Sharding::Threads(2));
            let concurrent: Vec<_> = std::thread::scope(|s| {
                let joins: Vec<_> = (0..THREADS)
                    .map(|t| {
                        let h = sharded_handle.clone();
                        s.spawn(move || run_workload(&h, domain, t, 3))
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });

            for (t, (a, b)) in serial.iter().zip(&concurrent).enumerate() {
                assert_bitwise_equal(
                    a,
                    b,
                    &format!("{stencil_name} O{level} threads x slabs, thread {t}"),
                );
            }
            // The inner layer really ran sharded.
            let timing = coord.metrics.get(stencil_name, "vector").unwrap();
            assert_eq!(
                timing.max_threads, 2,
                "{stencil_name} O{level}: inner sharding did not engage"
            );
        }
    }
}

/// The ROADMAP's sharding prerequisite, demonstrated directly: one
/// *shared* compiled artifact (same fingerprint, same backend instance)
/// dispatching from many threads with distinct domains concurrently.
#[test]
fn concurrent_distinct_domains_on_one_handle() {
    let mut coord = Coordinator::with_opt_level(OptLevel::O3);
    coord.set_sharding(Sharding::from_env());
    let handle = coord.stencil_library("hdiff", "vector").unwrap();
    let domains = [[6, 6, 3], [9, 7, 4], [12, 10, 6], [7, 11, 2]];
    let serial: Vec<_> = domains
        .iter()
        .map(|d| run_workload(&handle, *d, 17, 2))
        .collect();
    let concurrent: Vec<_> = std::thread::scope(|s| {
        let joins: Vec<_> = domains
            .iter()
            .map(|d| {
                let h = handle.clone();
                s.spawn(move || run_workload(&h, *d, 17, 2))
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    for (d, (a, b)) in domains.iter().zip(serial.iter().zip(&concurrent)) {
        assert_bitwise_equal(a, b, &format!("hdiff O3 vector domain {d:?}"));
    }
}

/// (b) of the acceptance criteria, timing half: a `BoundInvocation`'s
/// repeat call reports validation time at least an order of magnitude
/// below the first call's full validation. A wide stencil (many fields)
/// makes the full validation measurably heavy; timing noise is absorbed
/// by retrying on fresh binds.
#[test]
fn repeat_call_validation_is_an_order_of_magnitude_cheaper() {
    // Generate a stencil with many field parameters.
    const NFIELDS: usize = 24;
    let params: Vec<String> =
        (0..NFIELDS).map(|i| format!("f{i}: Field<f64>")).collect();
    // Every parameter participates (the pipeline rejects unused fields).
    let sum: Vec<String> = (0..NFIELDS).map(|i| format!("f{i}")).collect();
    let src = format!(
        "stencil wide({}, out: Field<f64>) {{\n\
           with computation(PARALLEL), interval(...) {{ out = {}; }}\n\
         }}",
        params.join(", "),
        sum.join(" + ")
    );
    let mut coord = Coordinator::new();
    let handle = coord.stencil(&src, "wide", "vector", &Default::default()).unwrap();
    let domain = [6, 6, 2];
    let mut fields = seeded_fields(&handle, domain, 3);

    let mut best_ratio = f64::INFINITY;
    for _attempt in 0..8 {
        let mut inv = bind(&handle, &fields, &[], domain);
        let first = {
            let mut refs: Vec<&mut Storage> =
                fields.iter_mut().map(|(_, s)| s).collect();
            inv.run(&mut refs).unwrap()
        };
        let second = {
            let mut refs: Vec<&mut Storage> =
                fields.iter_mut().map(|(_, s)| s).collect();
            inv.run(&mut refs).unwrap()
        };
        assert!(first.checks >= inv.bind_validation_time());
        let ratio = second.checks.as_secs_f64() / first.checks.as_secs_f64().max(1e-12);
        best_ratio = best_ratio.min(ratio);
        if second.checks.as_secs_f64() * 10.0 <= first.checks.as_secs_f64() {
            return; // order-of-magnitude gap demonstrated
        }
    }
    panic!(
        "repeat-call validation never reached 10x below full validation \
         (best ratio {best_ratio:.4})"
    );
}

/// (b) of the acceptance criteria, semantics half: after a storage is
/// reallocated with a different geometry the bound invocation refuses to
/// run until re-bound; with the original geometry restored it keeps
/// working.
#[test]
fn bind_once_semantics_catch_stale_storages() {
    let mut coord = Coordinator::new();
    let handle = coord.stencil_library("hdiff", "vector").unwrap();
    let domain = [8, 7, 4];
    let mut fields = seeded_fields(&handle, domain, 5);
    let mut inv = bind(&handle, &fields, &[], domain);
    {
        let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
        inv.run(&mut refs).unwrap();
    }

    // Reallocate in_phi with a halo the bind never saw.
    let stale = std::mem::replace(
        &mut fields[0].1,
        Storage::with_halo(domain, 3), // hdiff binds halo-2 storages
    );
    {
        let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
        let err = inv.run(&mut refs).unwrap_err();
        assert!(
            format!("{err:#}").contains("re-bind"),
            "stale geometry must demand a re-bind: {err:#}"
        );
    }

    // Restoring the original storage satisfies the bound snapshot again.
    fields[0].1 = stale;
    {
        let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
        inv.run(&mut refs).unwrap();
    }

    // Wrong arity is caught before dispatch, too.
    let (_, first) = fields.first_mut().unwrap();
    assert!(inv.run(&mut [first]).is_err());
}

/// Handles record into the coordinator's shared metrics from any thread.
#[test]
fn concurrent_runs_share_metrics() {
    let mut coord = Coordinator::new();
    let handle = coord.stencil_library("laplacian", "vector").unwrap();
    let domain = [6, 6, 2];
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let h = handle.clone();
            s.spawn(move || {
                run_workload(&h, domain, t, 2);
            });
        }
    });
    let timing = coord.metrics.get("laplacian", "vector").unwrap();
    assert_eq!(timing.calls, 8, "4 threads x 2 calls must all be recorded");
}
