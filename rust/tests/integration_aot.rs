//! AOT-pipeline integration: the full L1→L2→L3 path. Requires
//! `make artifacts`; each test skips loudly when artifacts are absent.

use gt4rs::runtime::{Arg, Runtime};
use gt4rs::storage::Storage;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(name: &str) -> bool {
    artifacts_dir().join(name).is_file()
}

#[test]
fn model_step_artifact_composes_hdiff_and_vadv() {
    if gt4rs::runtime::skip_test_without_pjrt("model_step_artifact_composes_hdiff_and_vadv") {
        return;
    }
    // The L2 `model_step` artifact fuses the Pallas hdiff + vadv kernels in
    // one XLA program; its output must equal running the two library
    // stencils back-to-back on the debug backend.
    let name = "model_step_12x10x6.hlo.txt";
    if !have(name) {
        eprintln!("SKIP: {name} missing — run `make artifacts`");
        return;
    }
    let domain = [12usize, 10, 6];
    let [ni, nj, nk] = domain;
    let dtdz = 0.25;

    // inputs
    let mut phi_box = Storage::with_horizontal_halo(domain, 2);
    let mut coeff = Storage::with_halo(domain, 0);
    let mut w = Storage::with_halo(domain, 0);
    let mut seed = 3u64;
    let mut rnd = move || {
        seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        ((seed >> 33) as f64) / (u32::MAX as f64) - 0.5
    };
    let h = phi_box.info.halo;
    for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
        for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
            for k in 0..nk as i64 {
                phi_box.set(i, j, k, rnd());
            }
        }
    }
    for i in 0..ni as i64 {
        for j in 0..nj as i64 {
            for k in 0..nk as i64 {
                coeff.set(i, j, k, 0.02 + 0.01 * rnd());
                w.set(i, j, k, rnd());
            }
        }
    }

    // Path A: the fused L2 artifact via the raw runtime.
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo_text(artifacts_dir().join(name)).unwrap();
    let phi_data = phi_box.to_c_order();
    let coeff_data = coeff.domain_to_c_order();
    let w_data = w.domain_to_c_order();
    let outputs = exe
        .run_f64(&[
            Arg::F64(&phi_data, vec![ni + 4, nj + 4, nk]),
            Arg::F64(&coeff_data, vec![ni, nj, nk]),
            Arg::F64(&w_data, vec![ni, nj, nk]),
            Arg::Scalar(dtdz),
        ])
        .unwrap();
    assert_eq!(outputs.len(), 1);

    // Path B: library hdiff then vadv on the debug backend, via handles.
    let mut coord = gt4rs::coordinator::Coordinator::new();
    let hdiff = coord.stencil_library("hdiff", "debug").unwrap();
    let vadv = coord.stencil_library("vadv", "debug").unwrap();
    let mut out = Storage::with_halo(domain, 0);
    hdiff
        .bind()
        .field("in_phi", &phi_box)
        .field("coeff", &coeff)
        .field("out_phi", &out)
        .domain(domain)
        .finish()
        .unwrap()
        .run(&mut [&mut phi_box, &mut coeff, &mut out])
        .unwrap();
    vadv.bind()
        .field("phi", &out)
        .field("w", &w)
        .scalar("dtdz", dtdz)
        .domain(domain)
        .finish()
        .unwrap()
        .run(&mut [&mut out, &mut w])
        .unwrap();

    let expected = out.domain_to_c_order();
    let mut max_d: f64 = 0.0;
    for (a, b) in outputs[0].iter().zip(&expected) {
        max_d = max_d.max((a - b).abs());
    }
    assert!(max_d < 1e-12, "fused L2 artifact differs from L3 composition by {max_d}");
}

#[test]
fn model_runs_on_pjrt_aot_backend() {
    if gt4rs::runtime::skip_test_without_pjrt("model_runs_on_pjrt_aot_backend") {
        return;
    }
    if !have("hdiff_32x32x8.hlo.txt") {
        eprintln!("SKIP: model artifacts missing — run `make artifacts`");
        return;
    }
    use gt4rs::model::{IsentropicModel, ModelConfig};
    let cfg = ModelConfig {
        domain: [32, 32, 8],
        backend: "pjrt-aot".to_string(),
        ..ModelConfig::default()
    };
    let mut m_aot = IsentropicModel::new(cfg.clone()).unwrap();
    let mut m_ref = IsentropicModel::new(ModelConfig {
        backend: "debug".to_string(),
        ..cfg
    })
    .unwrap();
    m_aot.run(3).unwrap();
    m_ref.run(3).unwrap();
    let d = m_aot.phi_snapshot().max_abs_diff(&m_ref.phi_snapshot());
    assert!(d < 1e-11, "pjrt-aot model trajectory differs by {d}");
}

#[test]
fn artifact_roundtrip_hdiff_all_test_domains() {
    if gt4rs::runtime::skip_test_without_pjrt("artifact_roundtrip_hdiff_all_test_domains") {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    for domain in [[8usize, 8, 4], [12, 10, 6]] {
        let name = format!("hdiff_{}x{}x{}.hlo.txt", domain[0], domain[1], domain[2]);
        if !have(&name) {
            eprintln!("SKIP: {name} missing");
            continue;
        }
        let exe = rt.load_hlo_text(artifacts_dir().join(&name)).unwrap();
        let [ni, nj, nk] = domain;
        let in_data = vec![1.5f64; (ni + 4) * (nj + 4) * nk];
        let coeff = vec![0.1f64; ni * nj * nk];
        let out_in = vec![0.0f64; ni * nj * nk];
        let outputs = exe
            .run_f64(&[
                Arg::F64(&in_data, vec![ni + 4, nj + 4, nk]),
                Arg::F64(&coeff, vec![ni, nj, nk]),
                Arg::F64(&out_in, vec![ni, nj, nk]),
            ])
            .unwrap();
        // constant field: diffusion is identity
        for v in &outputs[0] {
            assert!((v - 1.5).abs() < 1e-14);
        }
    }
}
