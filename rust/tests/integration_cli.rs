//! CLI integration: drive the `repro` binary end-to-end, the way a user
//! (or the paper's Fig. 4 Jupyter workflow analog) would.

use std::process::Command;

fn repro(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn repro");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands_and_backends() {
    let (ok, text) = repro(&["help"]);
    assert!(ok);
    for needle in ["inspect", "run", "validate", "bench", "model", "pjrt-aot", "hdiff"] {
        assert!(text.contains(needle), "help missing `{needle}`:\n{text}");
    }
}

#[test]
fn inspect_dumps_ir() {
    let (ok, text) = repro(&["inspect", "--stencil", "hdiff"]);
    assert!(ok, "{text}");
    assert!(text.contains("stencil hdiff"));
    assert!(text.contains("fingerprint"));
    assert!(text.contains("multistage 0 PARALLEL"));
    assert!(text.contains("extent"));
}

#[test]
fn inspect_honors_externals() {
    let (ok, a) = repro(&["inspect", "--stencil", "diffusion"]);
    assert!(ok, "{a}");
    let (ok, b) = repro(&["inspect", "--stencil", "diffusion", "--externals", "LIM=0.5"]);
    assert!(ok, "{b}");
    let fp = |t: &str| {
        t.lines()
            .next()
            .unwrap()
            .split("fingerprint ")
            .nth(1)
            .unwrap()
            .trim_end_matches(')')
            .to_string()
    };
    assert_ne!(fp(&a), fp(&b), "externals must change the fingerprint");
}

#[test]
fn run_reports_timing_and_checksum() {
    let (ok, text) = repro(&[
        "run", "--stencil", "laplacian", "--backend", "vector", "--domain", "16x16x4",
        "--iters", "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("iter 0"));
    assert!(text.contains("domain sum"));
}

#[test]
fn validate_cross_checks_backends() {
    let (ok, text) = repro(&[
        "validate", "--stencil", "vadv", "--domain", "8x8x10",
        "--backends", "debug,vector,xla",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("OK"));
    assert!(!text.contains("MISMATCH"), "{text}");
}

#[test]
fn model_runs_and_reports_mass() {
    let (ok, text) = repro(&[
        "model", "--steps", "5", "--domain", "12x12x4", "--backend", "vector",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("mass"));
    assert!(text.contains("total wall"));
}

#[test]
fn ir_dump_shows_passes() {
    let (ok, text) = repro(&["ir", "--stencil", "hdiff"]);
    assert!(ok, "{text}");
    assert!(text.contains("pre-opt"));
    for pass in ["fold-cse", "dce", "fuse", "demote"] {
        assert!(text.contains(&format!("after pass `{pass}`")), "missing `{pass}`:\n{text}");
    }
    // Demotion must actually fire on hdiff (its temporaries are read at
    // horizontal offsets: plane scratch).
    assert!(text.contains("[plane]"), "no demoted temporaries:\n{text}");
    // At --opt-level 0 every pass is disabled.
    let (ok0, text0) = repro(&["ir", "--stencil", "hdiff", "--opt-level", "0"]);
    assert!(ok0, "{text0}");
    assert!(text0.contains("disabled at --opt-level 0"));
    assert!(!text0.contains("[plane]"));
    assert!(!text0.contains("[register]"));
}

#[test]
fn opt_levels_produce_identical_checksums() {
    // `run` prints per-field domain sums; they must be bit-identical
    // across opt levels on the vector backend.
    let sums = |level: &str| {
        let (ok, text) = repro(&[
            "run", "--stencil", "hdiff", "--backend", "vector", "--domain", "18x14x6",
            "--iters", "1", "--opt-level", level,
        ]);
        assert!(ok, "{text}");
        let lines: Vec<String> = text
            .lines()
            .filter(|l| l.contains("domain sum"))
            .map(str::to_string)
            .collect();
        assert!(!lines.is_empty(), "{text}");
        lines
    };
    assert_eq!(sums("0"), sums("2"));
    // Opt-level 3 (fused loop-nest evaluator) is bit-identical too.
    assert_eq!(sums("0"), sums("3"));
}

/// Minimal structural JSON validator (no serde in the offline crate set):
/// checks the value grammar — objects, arrays, strings with escapes,
/// numbers (incl. exponents), booleans, null — and full input consumption.
fn assert_valid_json(text: &str) {
    fn skip_ws(b: &[u8], mut i: usize) -> usize {
        while i < b.len() && (b[i] as char).is_ascii_whitespace() {
            i += 1;
        }
        i
    }
    fn value(b: &[u8], i: usize) -> Result<usize, String> {
        let i = skip_ws(b, i);
        let Some(&c) = b.get(i) else { return Err("eof".into()) };
        match c {
            b'{' => {
                let mut i = skip_ws(b, i + 1);
                if b.get(i) == Some(&b'}') {
                    return Ok(i + 1);
                }
                loop {
                    i = string(b, skip_ws(b, i))?;
                    i = skip_ws(b, i);
                    if b.get(i) != Some(&b':') {
                        return Err(format!("expected ':' at {i}"));
                    }
                    i = value(b, i + 1)?;
                    i = skip_ws(b, i);
                    match b.get(i) {
                        Some(&b',') => i += 1,
                        Some(&b'}') => return Ok(i + 1),
                        _ => return Err(format!("expected ',' or '}}' at {i}")),
                    }
                }
            }
            b'[' => {
                let mut i = skip_ws(b, i + 1);
                if b.get(i) == Some(&b']') {
                    return Ok(i + 1);
                }
                loop {
                    i = value(b, i)?;
                    i = skip_ws(b, i);
                    match b.get(i) {
                        Some(&b',') => i += 1,
                        Some(&b']') => return Ok(i + 1),
                        _ => return Err(format!("expected ',' or ']' at {i}")),
                    }
                }
            }
            b'"' => string(b, i),
            b't' => lit(b, i, "true"),
            b'f' => lit(b, i, "false"),
            b'n' => lit(b, i, "null"),
            _ => number(b, i),
        }
    }
    fn lit(b: &[u8], i: usize, s: &str) -> Result<usize, String> {
        if b[i..].starts_with(s.as_bytes()) {
            Ok(i + s.len())
        } else {
            Err(format!("bad literal at {i}"))
        }
    }
    fn string(b: &[u8], i: usize) -> Result<usize, String> {
        if b.get(i) != Some(&b'"') {
            return Err(format!("expected string at {i}"));
        }
        let mut i = i + 1;
        while let Some(&c) = b.get(i) {
            match c {
                b'"' => return Ok(i + 1),
                b'\\' => i += 2,
                _ => i += 1,
            }
        }
        Err("unterminated string".into())
    }
    fn number(b: &[u8], mut i: usize) -> Result<usize, String> {
        let start = i;
        if b.get(i) == Some(&b'-') {
            i += 1;
        }
        let digits = |b: &[u8], mut i: usize| {
            let s = i;
            while i < b.len() && b[i].is_ascii_digit() {
                i += 1;
            }
            (i, i > s)
        };
        let (ni, ok) = digits(b, i);
        if !ok {
            return Err(format!("expected number at {start}"));
        }
        i = ni;
        if b.get(i) == Some(&b'.') {
            let (ni, ok) = digits(b, i + 1);
            if !ok {
                return Err(format!("bad fraction at {i}"));
            }
            i = ni;
        }
        if matches!(b.get(i), Some(&b'e') | Some(&b'E')) {
            i += 1;
            if matches!(b.get(i), Some(&b'+') | Some(&b'-')) {
                i += 1;
            }
            let (ni, ok) = digits(b, i);
            if !ok {
                return Err(format!("bad exponent at {i}"));
            }
            i = ni;
        }
        Ok(i)
    }
    let b = text.as_bytes();
    match value(b, 0) {
        Ok(end) => {
            let end = skip_ws(b, end);
            assert_eq!(end, b.len(), "trailing garbage after JSON:\n{text}");
        }
        Err(e) => panic!("invalid JSON ({e}):\n{text}"),
    }
}

#[test]
fn run_json_emits_parseable_json() {
    let (ok, text) = repro(&[
        "run", "--stencil", "laplacian", "--backend", "vector", "--domain", "8x8x4",
        "--iters", "2", "--json",
    ]);
    assert!(ok, "{text}");
    let line = text
        .lines()
        .find(|l| l.trim_start().starts_with('{'))
        .unwrap_or_else(|| panic!("no JSON object in output:\n{text}"));
    assert_valid_json(line.trim());
    for needle in [
        "\"stencil\":\"laplacian\"",
        "\"backend\":\"vector\"",
        "\"execute_ns\"",
        "\"checks_ns\"",
        "\"domain_sum\"",
        "\"checks_enabled\":true",
    ] {
        assert!(line.contains(needle), "missing `{needle}` in:\n{line}");
    }
}

#[test]
fn bench_json_emits_parseable_rows() {
    let (ok, text) = repro(&[
        "bench", "--stencil", "hdiff", "--domains", "8x8x4", "--iters", "1",
        "--backends", "vector", "--json",
    ]);
    assert!(ok, "{text}");
    let line = text
        .lines()
        .find(|l| l.trim_start().starts_with('['))
        .unwrap_or_else(|| panic!("no JSON array in output:\n{text}"));
    assert_valid_json(line.trim());
    assert!(line.contains("\"mean_ns\""), "{line}");
}

#[test]
fn threads_flag_shards_without_changing_checksums() {
    // --threads 2 must report the effective thread count in --json and
    // produce bit-identical domain sums to --threads off.
    let sums = |threads: &str| {
        let (ok, text) = repro(&[
            "run", "--stencil", "hdiff", "--backend", "vector", "--domain", "20x14x6",
            "--iters", "1", "--opt-level", "3", "--threads", threads,
        ]);
        assert!(ok, "{text}");
        let lines: Vec<String> = text
            .lines()
            .filter(|l| l.contains("domain sum"))
            .map(str::to_string)
            .collect();
        assert!(!lines.is_empty(), "{text}");
        lines
    };
    assert_eq!(sums("off"), sums("2"));
    assert_eq!(sums("off"), sums("4"));

    let (ok, text) = repro(&[
        "run", "--stencil", "hdiff", "--backend", "vector", "--domain", "20x14x6",
        "--iters", "1", "--opt-level", "3", "--threads", "2", "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"threads_used\":2"), "{text}");
    assert!(text.contains("\"sharding\":\"2\""), "{text}");

    // Auto on a tiny domain must degrade — and must say so.
    let (ok, text) = repro(&[
        "run", "--stencil", "hdiff", "--backend", "vector", "--domain", "8x8x4",
        "--iters", "1", "--threads", "auto", "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"threads_used\":1"), "degraded Auto must report 1:\n{text}");

    // A bad value fails cleanly.
    let (ok, text) = repro(&["run", "--stencil", "hdiff", "--threads", "banana"]);
    assert!(!ok);
    assert!(text.contains("--threads"), "{text}");
}

#[test]
fn dtype_flag_runs_all_stencils_and_changes_results() {
    // `--dtype f32` must execute every library stencil at every opt
    // level, both executor tiers, sharded and serial — and must report
    // the dtype in --json.
    for stencil in ["laplacian", "diffuse", "hdiff", "vadv"] {
        for level in ["0", "1", "2", "3"] {
            for (tier, threads) in
                [("interpreted", "off"), ("specialized", "off"), ("specialized", "2")]
            {
                let (ok, text) = repro(&[
                    "run", "--stencil", stencil, "--backend", "vector", "--domain",
                    "12x10x6", "--iters", "1", "--opt-level", level, "--tier", tier,
                    "--threads", threads, "--dtype", "f32",
                ]);
                assert!(ok, "{stencil} O{level} {tier} threads={threads}:\n{text}");
                assert!(text.contains("domain sum"), "{text}");
            }
        }
    }
    let (ok, text) = repro(&[
        "run", "--stencil", "hdiff", "--backend", "vector", "--domain", "12x10x6",
        "--iters", "1", "--dtype", "f32", "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"dtype\":\"f32\""), "{text}");

    // The precision knob must actually change the computed bits.
    let sum = |dtype: &str| {
        let (ok, text) = repro(&[
            "run", "--stencil", "hdiff", "--backend", "vector", "--domain", "12x10x6",
            "--iters", "1", "--dtype", dtype,
        ]);
        assert!(ok, "{text}");
        text.lines()
            .filter(|l| l.contains("domain sum"))
            .map(str::to_string)
            .collect::<Vec<_>>()
    };
    assert_ne!(sum("f32"), sum("f64"), "f32 run produced f64 bits");

    // A bad value fails cleanly.
    let (ok, text) = repro(&["run", "--stencil", "hdiff", "--dtype", "f16"]);
    assert!(!ok);
    assert!(text.contains("--dtype"), "{text}");
}

#[test]
fn model_precision_sweep_reports_per_stencil_errors() {
    let (ok, text) = repro(&[
        "model", "--steps", "4", "--domain", "12x12x4", "--backend", "vector",
        "--precision-sweep",
    ]);
    assert!(ok, "{text}");
    for needle in ["rel_l2", "upwind_advect", "hdiff", "vadv", "model(4 steps)", "ok"] {
        assert!(text.contains(needle), "missing `{needle}`:\n{text}");
    }
    assert!(!text.contains("FAIL"), "{text}");
}

#[test]
fn no_checks_flag_disables_validation() {
    let (ok, text) = repro(&[
        "run", "--stencil", "laplacian", "--backend", "vector", "--domain", "8x8x4",
        "--iters", "1", "--no-checks", "--json",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("\"checks_enabled\":false"), "{text}");
    assert!(text.contains("\"checks_ns\":0"), "{text}");
}

#[test]
fn unknown_flags_and_commands_fail_cleanly() {
    let (ok, text) = repro(&["warp"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
    let (ok2, text2) = repro(&["run", "--stencil"]);
    assert!(!ok2);
    assert!(text2.contains("needs a value"));
    let (ok3, text3) = repro(&["run", "--stencil", "hdiff", "--domain", "3x3"]);
    assert!(!ok3);
    assert!(text3.contains("three components"));
}

#[test]
fn run_from_gts_file() {
    let dir = std::env::temp_dir().join(format!("gt4rs_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("user.gts");
    std::fs::write(
        &path,
        "stencil double(a: Field<f64>, b: Field<f64>) {\n\
           with computation(PARALLEL), interval(...) { b = a * 2.0; }\n\
         }",
    )
    .unwrap();
    let (ok, text) = repro(&[
        "run", "--stencil", "double", "--file", path.to_str().unwrap(),
        "--backend", "debug", "--domain", "8x8x2", "--iters", "1",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("domain sum"));
    let _ = std::fs::remove_dir_all(&dir);
}
