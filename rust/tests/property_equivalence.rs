//! Property-based backend equivalence (in-repo generator: the offline
//! crate set has no proptest, so this uses a deterministic LCG over seeds
//! — same idea: many generated programs, one invariant).
//!
//! Invariants:
//! * for any well-formed stencil program, `debug` (reference interpreter),
//!   `vector` and `xla` produce identical fields (up to reassociation
//!   noise for `xla`);
//! * **every optimization level produces identical results**: the pass
//!   manager (fold-cse, dce, fuse, demote) is semantics-preserving, so
//!   `--opt-level 1` and `--opt-level 2` outputs are *bitwise* equal to
//!   the unoptimized `--opt-level 0` reference on the interpreting
//!   backends;
//! * `--opt-level 3` — the vector backend's **fused loop-nest evaluator**
//!   (group tapes, cross-stage CSE, register/plane/ring locals) — is
//!   bitwise identical to both the `debug` reference and the materializing
//!   vector path, including sweep carries demoted to the plane ring
//!   (vertical offsets on demoted temporaries);
//! * **intra-call domain sharding never changes a bit**: every
//!   `Threads(n)` plan is bitwise identical to `Off` at every opt level
//!   (swept explicitly below, and the whole suite re-runs under any plan
//!   named by `REPRO_THREADS` — the hosted CI thread-matrix exports 1/2/8
//!   on real multi-core runners);
//! * sequential sweeps whose carry crosses slab boundaries (horizontal
//!   field reads at `k±1`, and same-level cross-stage consumers) run
//!   **sharded through the per-level/per-stage halo exchange** and stay
//!   bitwise identical to the same-dtype debug reference over the full
//!   O0–O3 × executor-tier × `Threads(1..=4)` × {f64,f32} matrix;
//! * the O3 **specialized kernel-plan executor** (`ExecTier::Specialized`,
//!   the default) is bitwise identical to the interpreted tape walk and to
//!   the debug reference under every sharding plan; fast-math relaxation
//!   is opt-in, separately fingerprinted, tolerance-bounded, and never
//!   engages outside the specialized tier.

use gt4rs::coordinator::Coordinator;
use gt4rs::dsl::parser::parse_module;
use gt4rs::opt::OptLevel;
use gt4rs::storage::Storage;
use gt4rs::{ExecTier, Sharding};

const LEVELS: [OptLevel; 4] =
    [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];

struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 33
    }
    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
    fn f64(&mut self) -> f64 {
        (self.next() as f64) / (u32::MAX as f64) - 0.5
    }
    fn offset(&mut self, max: i64) -> i64 {
        self.below(2 * max as u64 + 1) as i64 - max
    }
}

/// Generate a random point-wise expression over `vars` (field names) and
/// `scalars`, with offsets bounded by ±2 and numerically-safe builtins.
fn gen_expr(rng: &mut Rng, vars: &[String], scalars: &[&str], depth: usize) -> String {
    if depth == 0 || rng.below(4) == 0 {
        return match rng.below(4) {
            0 => format!("{:.3}", rng.f64() * 2.0),
            1 => scalars[rng.below(scalars.len() as u64) as usize].to_string(),
            _ => {
                let v = &vars[rng.below(vars.len() as u64) as usize];
                let (i, j, k) = (rng.offset(2), rng.offset(2), 0);
                format!("{v}[{i},{j},{k}]")
            }
        };
    }
    let a = gen_expr(rng, vars, scalars, depth - 1);
    let b = gen_expr(rng, vars, scalars, depth - 1);
    match rng.below(8) {
        0 => format!("({a} + {b})"),
        1 => format!("({a} - {b})"),
        2 => format!("({a} * {b})"),
        // division guarded away from zero
        3 => format!("({a} / (2.0 + abs({b})))"),
        4 => format!("min({a}, {b})"),
        5 => format!("max({a}, {b})"),
        6 => format!("sqrt(abs({a}))"),
        _ => format!("({a} > {b} ? {a} : {b})"),
    }
}

/// Generate a random PARALLEL stencil: a chain of temporaries feeding an
/// output field, exercising extents, temporaries, builtins and ternaries
/// (and, at higher opt levels, fusion/demotion/CSE over all of them).
fn gen_stencil(seed: u64) -> String {
    let mut rng = Rng(seed);
    let n_temps = 1 + rng.below(3) as usize;
    let mut vars = vec!["a".to_string(), "b".to_string()];
    let scalars = ["s1", "s2"];
    let mut body = String::new();
    for t in 0..n_temps {
        let name = format!("t{t}");
        let expr = gen_expr(&mut rng, &vars, &scalars, 3);
        body.push_str(&format!("        {name} = {expr};\n"));
        vars.push(name);
    }
    let out_expr = gen_expr(&mut rng, &vars, &scalars, 3);
    // Guarantee both inputs participate (the pipeline rejects unused
    // field parameters, by design).
    body.push_str(&format!(
        "        out = {out_expr} + 0.125 * (a[0,0,0] - b[0,0,0]);\n"
    ));
    format!(
        "stencil prop(a: Field<f64>, b: Field<f64>, out: Field<f64>; s1: f64, s2: f64) {{\n\
            with computation(PARALLEL), interval(...) {{\n{body}    }}\n}}"
    )
}

fn run_backend(
    coord: &mut Coordinator,
    fp: u64,
    be: &str,
    domain: [usize; 3],
    seed: u64,
    scalars: &[(&str, f64)],
) -> Vec<(String, Storage)> {
    // The CI thread-matrix reaches every leg of this suite here: any plan
    // in REPRO_THREADS applies to all handles (backends without a sharded
    // path ignore it, by the Backend contract).
    coord.set_sharding(Sharding::from_env());
    let handle = coord
        .stencil_for(fp, be)
        .unwrap_or_else(|e| panic!("seed {seed} backend {be}: {e:#}"));
    let mut rng = Rng(seed ^ 0xabcdef);
    let mut fields: Vec<(String, Storage)> = handle
        .ir()
        .fields
        .iter()
        .map(|f| {
            let mut s = handle.alloc_field(&f.name, domain).unwrap();
            let [ni, nj, nk] = domain;
            let h = s.info.halo;
            for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                    for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                        s.set(i, j, k, rng.f64());
                    }
                }
            }
            (f.name.clone(), s)
        })
        .collect();
    let mut inv = handle
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(scalars)
        .finish()
        .unwrap_or_else(|e| panic!("seed {seed} backend {be}: {e:#}"));
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs)
        .unwrap_or_else(|e| panic!("seed {seed} backend {be}: {e:#}"));
    fields
}

fn assert_fields_match(
    reference: &[(String, Storage)],
    got: &[(String, Storage)],
    tol: f64,
    context: &str,
) {
    for ((n, r), (_, v)) in reference.iter().zip(got) {
        let d = r.max_abs_diff(v);
        assert!(d <= tol, "{context} field `{n}`: differs from reference by {d}");
    }
}

#[test]
fn random_parallel_stencils_agree_across_backends_and_opt_levels() {
    let domain = [7, 6, 3];
    let scalars = [("s1", 0.4), ("s2", -0.7)];
    let xla_ok = gt4rs::runtime::pjrt_available();
    if !xla_ok {
        eprintln!("SKIP xla legs: PJRT runtime unavailable");
    }
    for seed in 0..40u64 {
        let src = gen_stencil(seed);
        // The generated program must parse and analyze.
        parse_module(&src).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
        let fp0 = coord0
            .compile_source(&src, "prop", &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}\n{src}"));
        let reference = run_backend(&mut coord0, fp0, "debug", domain, seed, &scalars);

        for level in LEVELS {
            let mut coord = Coordinator::with_opt_level(level);
            let fp = coord.compile_source(&src, "prop", &Default::default()).unwrap();
            for be in ["debug", "vector"] {
                let got = run_backend(&mut coord, fp, be, domain, seed, &scalars);
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("seed {seed} O{level} {be}\n{src}\n"),
                );
            }
            // xla is the expensive leg: sweep a prefix of the seeds at the
            // extreme pass configurations only (O3 emits the same graph as
            // O2 — the fused bit only affects the vector backend).
            if xla_ok && seed < 12 && matches!(level, OptLevel::O0 | OptLevel::O2) {
                let got = run_backend(&mut coord, fp, "xla", domain, seed, &scalars);
                assert_fields_match(
                    &reference,
                    &got,
                    1e-12,
                    &format!("seed {seed} O{level} xla\n{src}\n"),
                );
            }
        }
    }
}

#[test]
fn random_sequential_accumulators_agree_across_backends_and_opt_levels() {
    // FORWARD/BACKWARD family with randomized coefficients: cumulative
    // recurrences x_k = alpha * x_(k-1) + expr(a).
    let domain = [5, 5, 9];
    let xla_ok = gt4rs::runtime::pjrt_available();
    for seed in 0..20u64 {
        let mut rng = Rng(seed.wrapping_mul(77).wrapping_add(13));
        let alpha = 0.1 + 0.8 * (rng.f64() + 0.5);
        let beta = rng.f64();
        let src = format!(
            "stencil seqprop(a: Field<f64>, x: Field<f64>) {{\n\
               with computation(FORWARD) {{\n\
                 interval(0, 1) {{ x = a * {beta:.4}; }}\n\
                 interval(1, None) {{ x = x[0,0,-1] * {alpha:.4} + a; }}\n\
               }}\n\
               with computation(BACKWARD) {{\n\
                 interval(-1, None) {{ x = x * 0.5; }}\n\
                 interval(0, -1) {{ x = (x[0,0,1] + x) * {alpha:.4}; }}\n\
               }}\n\
             }}"
        );
        let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
        let fp0 = coord0
            .compile_source(&src, "seqprop", &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
        let reference = run_backend(&mut coord0, fp0, "debug", domain, seed, &[]);
        for level in LEVELS {
            let mut coord = Coordinator::with_opt_level(level);
            let fp = coord.compile_source(&src, "seqprop", &Default::default()).unwrap();
            for be in ["debug", "vector"] {
                let got = run_backend(&mut coord, fp, be, domain, seed, &[]);
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("seed {seed} O{level} {be}"),
                );
            }
            if xla_ok && seed < 8 && matches!(level, OptLevel::O0 | OptLevel::O2) {
                let got = run_backend(&mut coord, fp, "xla", domain, seed, &[]);
                assert_fields_match(
                    &reference,
                    &got,
                    1e-12,
                    &format!("seed {seed} O{level} xla"),
                );
            }
        }
    }
}

#[test]
fn random_ring_carries_agree_across_backends_and_opt_levels() {
    // Sweep carries demoted to the plane ring (k-cache): temporaries
    // written and read (at vertical, and optionally horizontal, offsets)
    // inside one FORWARD/BACKWARD multistage. The fused evaluator must
    // stay bitwise equal to debug at every level.
    let domain = [6, 5, 8];
    for seed in 0..20u64 {
        let mut rng = Rng(seed.wrapping_mul(9173).wrapping_add(7));
        let alpha = 0.2 + 0.6 * (rng.f64() + 0.5);
        let beta = rng.f64();
        let horizontal = seed % 2 == 0;
        let (policy, first, rest, dk) = if seed % 3 == 0 {
            ("BACKWARD", "interval(-1, None)", "interval(0, -1)", 1)
        } else {
            ("FORWARD", "interval(0, 1)", "interval(1, None)", -1)
        };
        // Horizontal variant reads the carry plane at ±1: the temp chain
        // widens the writers' extents so the ring windows are covered.
        let consumer = if horizontal {
            format!("u = t[1,0,{dk}] + t[-1,0,{dk}]; x = u * 0.25;")
        } else {
            format!("x = t - t[0,0,{dk}] * {beta:.3};")
        };
        let consumer_first = if horizontal { "u = t; x = u;" } else { "x = t;" };
        let src = format!(
            "stencil rprop(a: Field<f64>, x: Field<f64>) {{\n\
               with computation({policy}) {{\n\
                 {first} {{ t = a * {beta:.3}; {consumer_first} }}\n\
                 {rest} {{ t = a + t[0,0,{dk}] * {alpha:.3}; {consumer} }}\n\
               }}\n\
             }}"
        );
        let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
        let fp0 = coord0
            .compile_source(&src, "rprop", &Default::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e:#}\n{src}"));
        let reference = run_backend(&mut coord0, fp0, "debug", domain, seed, &[]);
        for level in LEVELS {
            let mut coord = Coordinator::with_opt_level(level);
            let fp = coord.compile_source(&src, "rprop", &Default::default()).unwrap();
            for be in ["debug", "vector"] {
                let got = run_backend(&mut coord, fp, be, domain, seed, &[]);
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("seed {seed} O{level} {be}\n{src}\n"),
                );
            }
        }
    }
}

#[test]
fn stdlib_stencils_all_levels_bitwise_equal_on_vector() {
    // Every library stencil, every opt level, both interpreting backends:
    // bitwise equal to the unoptimized debug reference.
    let domain = [9, 8, 6];
    for name in gt4rs::stdlib::names() {
        let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
        let fp0 = coord0.compile_library(name).unwrap();
        let scalars: Vec<(String, f64)> = coord0
            .ir(fp0)
            .unwrap()
            .scalars
            .iter()
            .map(|s| (s.name.clone(), 0.21))
            .collect();
        let srefs: Vec<(&str, f64)> =
            scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
        let reference = run_backend(&mut coord0, fp0, "debug", domain, 7, &srefs);
        for level in LEVELS {
            let mut coord = Coordinator::with_opt_level(level);
            let fp = coord.compile_library(name).unwrap();
            for be in ["debug", "vector"] {
                let got = run_backend(&mut coord, fp, be, domain, 7, &srefs);
                assert_fields_match(&reference, &got, 0.0, &format!("{name} O{level} {be}"));
            }
        }
    }
}

#[test]
fn library_stencils_opt_levels_bitwise_equal() {
    // The acceptance workloads: hdiff and vadv at --opt-level 2 must be
    // bitwise identical to --opt-level 0 on both interpreting backends.
    let cases: [(&str, [usize; 3], &[(&str, f64)]); 2] = [
        ("hdiff", [12, 10, 6], &[]),
        ("vadv", [8, 8, 12], &[("dtdz", 0.3)]),
    ];
    for (stencil, domain, scalars) in cases {
        let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
        let fp0 = coord0.compile_library(stencil).unwrap();
        let reference = run_backend(&mut coord0, fp0, "debug", domain, 99, scalars);
        for level in LEVELS {
            let mut coord = Coordinator::with_opt_level(level);
            let fp = coord.compile_library(stencil).unwrap();
            for be in ["debug", "vector"] {
                let got = run_backend(&mut coord, fp, be, domain, 99, scalars);
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("{stencil} O{level} {be}"),
                );
            }
        }
    }
}

/// Run a compiled stencil on the vector backend with an explicit
/// per-invocation sharding override (ignoring `REPRO_THREADS`).
fn run_vector_with_sharding(
    coord: &mut Coordinator,
    fp: u64,
    domain: [usize; 3],
    seed: u64,
    scalars: &[(&str, f64)],
    sharding: Sharding,
) -> Vec<(String, Storage)> {
    coord.set_sharding(Sharding::Off);
    let handle = coord
        .stencil_for(fp, "vector")
        .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
    let mut rng = Rng(seed ^ 0xabcdef);
    let mut fields: Vec<(String, Storage)> = handle
        .ir()
        .fields
        .iter()
        .map(|f| {
            let mut s = handle.alloc_field(&f.name, domain).unwrap();
            let [ni, nj, nk] = domain;
            let h = s.info.halo;
            for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                    for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                        s.set(i, j, k, rng.f64());
                    }
                }
            }
            (f.name.clone(), s)
        })
        .collect();
    let mut inv = handle
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(scalars)
        .sharding(sharding)
        .finish()
        .unwrap();
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs)
        .unwrap_or_else(|e| panic!("seed {seed} sharding {sharding}: {e:#}"));
    fields
}

/// Like [`run_vector_with_sharding`], additionally overriding the fused
/// path's executor tier per invocation.
fn run_vector_with_tier(
    coord: &mut Coordinator,
    fp: u64,
    domain: [usize; 3],
    seed: u64,
    scalars: &[(&str, f64)],
    sharding: Sharding,
    tier: ExecTier,
) -> Vec<(String, Storage)> {
    coord.set_sharding(Sharding::Off);
    let handle = coord
        .stencil_for(fp, "vector")
        .unwrap_or_else(|e| panic!("seed {seed}: {e:#}"));
    let mut rng = Rng(seed ^ 0xabcdef);
    let mut fields: Vec<(String, Storage)> = handle
        .ir()
        .fields
        .iter()
        .map(|f| {
            let mut s = handle.alloc_field(&f.name, domain).unwrap();
            let [ni, nj, nk] = domain;
            let h = s.info.halo;
            for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
                for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
                    for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                        s.set(i, j, k, rng.f64());
                    }
                }
            }
            (f.name.clone(), s)
        })
        .collect();
    let mut inv = handle
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(scalars)
        .sharding(sharding)
        .exec_tier(tier)
        .finish()
        .unwrap();
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs)
        .unwrap_or_else(|e| panic!("seed {seed} {sharding} {tier}: {e:#}"));
    fields
}

#[test]
fn exec_tier_sweep_is_bitwise_identical_across_sharding_plans() {
    // The specialization honesty gate: at O3 the compiled kernel plans
    // (guard-hoisted interior blocks, cache-blocked j-tiles, fringe
    // strips) must be bitwise identical to the interpreted tape walk and
    // to the debug reference — for random PARALLEL programs and random
    // ring-carry sequential sweeps (the order-sensitive guarded-only
    // path), under serial and sharded schedules alike.
    let scalars = [("s1", 0.4), ("s2", -0.7)];
    let mut cases: Vec<(String, &str, [usize; 3], Vec<(&str, f64)>)> = Vec::new();
    for seed in 0..8u64 {
        cases.push((gen_stencil(seed), "prop", [11, 6, 4], scalars.to_vec()));
    }
    for seed in 0..8u64 {
        let mut rng = Rng(seed.wrapping_mul(9173).wrapping_add(7));
        let alpha = 0.2 + 0.6 * (rng.f64() + 0.5);
        let beta = rng.f64();
        let horizontal = seed % 2 == 0;
        let (policy, first, rest, dk) = if seed % 3 == 0 {
            ("BACKWARD", "interval(-1, None)", "interval(0, -1)", 1)
        } else {
            ("FORWARD", "interval(0, 1)", "interval(1, None)", -1)
        };
        let consumer = if horizontal {
            format!("u = t[1,0,{dk}] + t[-1,0,{dk}]; x = u * 0.25;")
        } else {
            format!("x = t - t[0,0,{dk}] * {beta:.3};")
        };
        let consumer_first = if horizontal { "u = t; x = u;" } else { "x = t;" };
        let src = format!(
            "stencil rprop(a: Field<f64>, x: Field<f64>) {{\n\
               with computation({policy}) {{\n\
                 {first} {{ t = a * {beta:.3}; {consumer_first} }}\n\
                 {rest} {{ t = a + t[0,0,{dk}] * {alpha:.3}; {consumer} }}\n\
               }}\n\
             }}"
        );
        cases.push((src, "rprop", [9, 5, 7], vec![]));
    }
    for (src, name, domain, scalars) in &cases {
        let mut coord = Coordinator::with_opt_level(OptLevel::O3);
        let fp = coord
            .compile_source(src, name, &Default::default())
            .unwrap_or_else(|e| panic!("{name}: {e:#}\n{src}"));
        let reference = run_backend(&mut coord, fp, "debug", *domain, 3, scalars);
        for sharding in [Sharding::Off, Sharding::Threads(2), Sharding::Threads(3)] {
            for tier in [ExecTier::Interpreted, ExecTier::Specialized] {
                let got =
                    run_vector_with_tier(&mut coord, fp, *domain, 3, scalars, sharding, tier);
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("{name} O3 {sharding} {tier}\n{src}\n"),
                );
            }
        }
    }
}

/// Max |value| over the compute domain — scales the fast-math tolerance.
fn max_abs(s: &Storage) -> f64 {
    let [ni, nj, nk] = s.info.shape;
    let mut m = 0.0f64;
    for i in 0..ni as i64 {
        for j in 0..nj as i64 {
            for k in 0..nk as i64 {
                m = m.max(s.get(i, j, k).abs());
            }
        }
    }
    m
}

#[test]
fn fast_math_is_tolerance_bounded_opt_in_with_distinct_fingerprints() {
    // The relaxed-numerics contract: fast-math (FMA contraction in the
    // specialized executor) is opt-in, salts every cache key, engages
    // *only* in the specialized kernel plans, and stays within a stated
    // bound — max |Δ| per field <= 1e-12 * (1 + max|reference|), a
    // generous multiple of the few-ulp error one contraction per value
    // can introduce on these workloads.
    let cases: [(&str, [usize; 3], &[(&str, f64)]); 2] = [
        ("hdiff", [12, 10, 6], &[]),
        ("vadv", [8, 8, 12], &[("dtdz", 0.3)]),
    ];
    for (name, domain, scalars) in cases {
        let mut exact = Coordinator::with_opt_level(OptLevel::O3);
        let fp_exact = exact.compile_library(name).unwrap();
        let mut relaxed = Coordinator::with_opt_level(OptLevel::O3);
        relaxed.set_fast_math(true);
        let fp_fm = relaxed.compile_library(name).unwrap();
        assert_ne!(fp_exact, fp_fm, "{name}: fast-math must salt the cache key");
        assert_ne!(
            exact.ir(fp_exact).unwrap().fingerprint,
            relaxed.ir(fp_fm).unwrap().fingerprint,
            "{name}: fast-math must change the IR fingerprint"
        );

        let reference = run_vector_with_tier(
            &mut exact,
            fp_exact,
            domain,
            11,
            scalars,
            Sharding::Off,
            ExecTier::Specialized,
        );
        // The interpreted tier walks the (unchanged) tape even under a
        // fast-math artifact: contraction lives only in the kernel plans,
        // so this leg stays bitwise exact — relaxation is never silently
        // substituted outside the specialized executor.
        let fm_interp = run_vector_with_tier(
            &mut relaxed,
            fp_fm,
            domain,
            11,
            scalars,
            Sharding::Off,
            ExecTier::Interpreted,
        );
        assert_fields_match(
            &reference,
            &fm_interp,
            0.0,
            &format!("{name} fast-math interpreted tier"),
        );
        // The specialized fast-math leg may contract: tolerance-bounded,
        // and deterministic under sharding (contraction is uniform across
        // the domain, so slab boundaries cannot change which ops fuse).
        let fm_spec = run_vector_with_tier(
            &mut relaxed,
            fp_fm,
            domain,
            11,
            scalars,
            Sharding::Off,
            ExecTier::Specialized,
        );
        for ((n, r), (_, v)) in reference.iter().zip(&fm_spec) {
            let tol = 1e-12 * (1.0 + max_abs(r));
            let d = r.max_abs_diff(v);
            assert!(
                d <= tol,
                "{name} fast-math specialized field `{n}`: |Δ| = {d:e} exceeds {tol:e}"
            );
        }
        let fm_sharded = run_vector_with_tier(
            &mut relaxed,
            fp_fm,
            domain,
            11,
            scalars,
            Sharding::Threads(3),
            ExecTier::Specialized,
        );
        assert_fields_match(
            &fm_spec,
            &fm_sharded,
            0.0,
            &format!("{name} fast-math specialized, sharded"),
        );
    }
}

#[test]
fn sharding_sweep_is_bitwise_identical_at_every_opt_level() {
    // The honesty core of the sharding feature: random PARALLEL programs
    // and random ring-carry sequential sweeps (horizontal and vertical
    // carry reads, FORWARD and BACKWARD) must be bitwise identical across
    // Threads(1..=4) vs Off at opt levels 0–3. Domains use awkward odd
    // widths so slab splits are uneven and narrower than the extents.
    let scalars = [("s1", 0.4), ("s2", -0.7)];
    let mut cases: Vec<(String, &str, [usize; 3], Vec<(&str, f64)>)> = Vec::new();
    for seed in 0..6u64 {
        cases.push((gen_stencil(seed), "prop", [11, 6, 4], scalars.to_vec()));
    }
    for seed in 0..6u64 {
        let mut rng = Rng(seed.wrapping_mul(9173).wrapping_add(7));
        let alpha = 0.2 + 0.6 * (rng.f64() + 0.5);
        let beta = rng.f64();
        let horizontal = seed % 2 == 0;
        let (policy, first, rest, dk) = if seed % 3 == 0 {
            ("BACKWARD", "interval(-1, None)", "interval(0, -1)", 1)
        } else {
            ("FORWARD", "interval(0, 1)", "interval(1, None)", -1)
        };
        let consumer = if horizontal {
            format!("u = t[1,0,{dk}] + t[-1,0,{dk}]; x = u * 0.25;")
        } else {
            format!("x = t - t[0,0,{dk}] * {beta:.3};")
        };
        let consumer_first = if horizontal { "u = t; x = u;" } else { "x = t;" };
        let src = format!(
            "stencil rprop(a: Field<f64>, x: Field<f64>) {{\n\
               with computation({policy}) {{\n\
                 {first} {{ t = a * {beta:.3}; {consumer_first} }}\n\
                 {rest} {{ t = a + t[0,0,{dk}] * {alpha:.3}; {consumer} }}\n\
               }}\n\
             }}"
        );
        cases.push((src, "rprop", [9, 5, 7], vec![]));
    }
    for (src, name, domain, scalars) in &cases {
        for level in LEVELS {
            let mut coord = Coordinator::with_opt_level(level);
            let fp = coord
                .compile_source(src, name, &Default::default())
                .unwrap_or_else(|e| panic!("{name}: {e:#}\n{src}"));
            let reference =
                run_vector_with_sharding(&mut coord, fp, *domain, 3, scalars, Sharding::Off);
            for threads in 1..=4usize {
                let got = run_vector_with_sharding(
                    &mut coord,
                    fp,
                    *domain,
                    3,
                    scalars,
                    Sharding::Threads(threads),
                );
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("{name} O{level} Threads({threads})\n{src}\n"),
                );
            }
        }
    }
}

#[test]
fn cross_slab_field_carries_are_bitwise_over_the_full_matrix() {
    // The halo-exchange honesty gate: random sequential multistages whose
    // carry is a *field* read at a horizontal offset — the shape that used
    // to degrade to the serial fallback — now run sharded through the
    // per-level (k±1 carries) or per-stage (same-level cross-stage
    // consumers) rendezvous, and must stay bitwise identical to the
    // same-dtype debug reference at every opt level × executor tier ×
    // thread count × dtype.
    use gt4rs::dsl::ast::DType;
    let domain = [10, 4, 6];
    let mut cases: Vec<String> = Vec::new();
    for seed in 0..6u64 {
        let mut rng = Rng(seed.wrapping_mul(40503).wrapping_add(99));
        let alpha = 0.2 + 0.5 * (rng.f64() + 0.5);
        let beta = rng.f64();
        let (policy, first, rest, dk) = if seed % 2 == 0 {
            ("FORWARD", "interval(0, 1)", "interval(1, None)", -1)
        } else {
            ("BACKWARD", "interval(-1, None)", "interval(0, -1)", 1)
        };
        let src = if seed % 3 != 2 {
            // Per-level exchange: the carry mixes the previous level's
            // left/right neighbor columns.
            format!(
                "stencil iprop(a: Field<f64>, x: Field<f64>) {{\n\
                   with computation({policy}) {{\n\
                     {first} {{ x = a * {beta:.3}; }}\n\
                     {rest} {{ x = a + (x[1,0,{dk}] + x[-1,0,{dk}]) * {alpha:.3}; }}\n\
                   }}\n\
                 }}"
            )
        } else {
            // Per-stage exchange: a later stage reads the sweep's target
            // at a same-level horizontal offset.
            format!(
                "stencil iprop(a: Field<f64>, x: Field<f64>, y: Field<f64>) {{\n\
                   with computation({policy}) {{\n\
                     {first} {{ x = a * {beta:.3}; y = x; }}\n\
                     {rest} {{ x = a + x[0,0,{dk}] * {alpha:.3}; \
                               y = x[1,0,0] + x[-1,0,0]; }}\n\
                   }}\n\
                 }}"
            )
        };
        cases.push(src);
    }
    for (seed, src) in cases.iter().enumerate() {
        let seed = seed as u64;
        for dtype in [DType::F64, DType::F32] {
            let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
            coord0.set_dtype(Some(dtype));
            let fp0 = coord0
                .compile_source(src, "iprop", &Default::default())
                .unwrap_or_else(|e| panic!("seed {seed}: {e:#}\n{src}"));
            let reference = run_backend(&mut coord0, fp0, "debug", domain, seed, &[]);
            for level in LEVELS {
                let mut coord = Coordinator::with_opt_level(level);
                coord.set_dtype(Some(dtype));
                let fp =
                    coord.compile_source(src, "iprop", &Default::default()).unwrap();
                for threads in 1..=4usize {
                    for tier in [ExecTier::Interpreted, ExecTier::Specialized] {
                        let got = run_vector_with_tier(
                            &mut coord,
                            fp,
                            domain,
                            seed,
                            &[],
                            Sharding::Threads(threads),
                            tier,
                        );
                        assert_fields_match(
                            &reference,
                            &got,
                            0.0,
                            &format!(
                                "seed {seed} {dtype} O{level} Threads({threads}) {tier}\n{src}\n"
                            ),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn sharding_reports_effective_thread_count() {
    // `Auto` on a domain narrower than one profitable slab must degrade
    // to serial — and `RunStats` must say so (never echo the plan).
    let mut coord = Coordinator::with_opt_level(OptLevel::O3);
    coord.set_sharding(Sharding::Auto);
    let fp = coord.compile_library("hdiff").unwrap();
    let handle = coord.stencil_for(fp, "vector").unwrap();
    let tiny = [8, 8, 4];
    let mut fields: Vec<(String, Storage)> = handle
        .ir()
        .fields
        .iter()
        .map(|f| (f.name.clone(), handle.alloc_field(&f.name, tiny).unwrap()))
        .collect();
    let mut inv = handle.bind().domain(tiny).fields(&fields).finish().unwrap();
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    let stats = inv.run(&mut refs).unwrap();
    assert_eq!(stats.threads_used(), 1, "Auto must degrade to Off on tiny domains");
    assert_eq!(stats.shard.slabs, 1);
    // An explicit plan on a wide-enough domain reports what it used.
    let domain = [24, 8, 4];
    let mut fields: Vec<(String, Storage)> = handle
        .ir()
        .fields
        .iter()
        .map(|f| (f.name.clone(), handle.alloc_field(&f.name, domain).unwrap()))
        .collect();
    let mut inv = handle
        .bind()
        .domain(domain)
        .fields(&fields)
        .sharding(Sharding::Threads(3))
        .finish()
        .unwrap();
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    let stats = inv.run(&mut refs).unwrap();
    assert_eq!(stats.threads_used(), 3);
    assert_eq!(stats.shard.slabs, 3);
}

#[test]
fn dtype_axis_is_bitwise_identical_to_same_dtype_debug() {
    // The dtype leg of the honesty contract: every library stencil under
    // an element-type override, at every opt level × executor tier ×
    // sharding plan, must be bitwise identical to the *same-dtype* debug
    // interpreter. (Cross-dtype agreement is neither expected nor wanted
    // — see the divergence check at the end.)
    use gt4rs::dsl::ast::DType;
    let domain = [9, 8, 6];
    for dtype in [DType::F64, DType::F32] {
        for name in gt4rs::stdlib::names() {
            let mut coord0 = Coordinator::with_opt_level(OptLevel::O0);
            coord0.set_dtype(Some(dtype));
            let fp0 = coord0.compile_library(name).unwrap();
            let scalars: Vec<(String, f64)> = coord0
                .ir(fp0)
                .unwrap()
                .scalars
                .iter()
                .map(|s| (s.name.clone(), 0.21))
                .collect();
            let srefs: Vec<(&str, f64)> =
                scalars.iter().map(|(n, v)| (n.as_str(), *v)).collect();
            let reference = run_backend(&mut coord0, fp0, "debug", domain, 7, &srefs);
            assert_eq!(
                reference[0].1.dtype(),
                dtype,
                "{name}: allocated storages must carry the override dtype"
            );
            for level in LEVELS {
                let mut coord = Coordinator::with_opt_level(level);
                coord.set_dtype(Some(dtype));
                let fp = coord.compile_library(name).unwrap();
                let got = run_backend(&mut coord, fp, "debug", domain, 7, &srefs);
                assert_fields_match(
                    &reference,
                    &got,
                    0.0,
                    &format!("{name} {dtype} O{level} debug"),
                );
                for sharding in [Sharding::Off, Sharding::Threads(2)] {
                    for tier in [ExecTier::Interpreted, ExecTier::Specialized] {
                        let got = run_vector_with_tier(
                            &mut coord, fp, domain, 7, &srefs, sharding, tier,
                        );
                        assert_fields_match(
                            &reference,
                            &got,
                            0.0,
                            &format!("{name} {dtype} O{level} {sharding} {tier}"),
                        );
                    }
                }
            }
        }
    }

    // And f32 must be *genuinely* single precision: distinct fingerprint,
    // different bits than the f64 run of the same program and inputs.
    let mut c64 = Coordinator::with_opt_level(OptLevel::O3);
    let fp64 = c64.compile_library("hdiff").unwrap();
    let r64 = run_backend(&mut c64, fp64, "vector", domain, 7, &[]);
    let mut c32 = Coordinator::with_opt_level(OptLevel::O3);
    c32.set_dtype(Some(DType::F32));
    let fp32 = c32.compile_library("hdiff").unwrap();
    assert_ne!(fp32, fp64, "dtype must salt the compilation cache key");
    let r32 = run_backend(&mut c32, fp32, "vector", domain, 7, &[]);
    let differs =
        r64.iter().zip(&r32).any(|((_, a), (_, b))| a.max_abs_diff(b) > 0.0);
    assert!(differs, "f32 run bitwise-matched f64 — storage silently widened");
}

#[test]
fn fingerprints_are_stable_and_distinct() {
    // Distinct generated programs (almost surely) have distinct
    // fingerprints; identical sources always collide.
    use std::collections::HashSet;
    let mut fps = HashSet::new();
    for seed in 0..40u64 {
        let src = gen_stencil(seed);
        let mut coord = Coordinator::new();
        let fp = coord.compile_source(&src, "prop", &Default::default()).unwrap();
        let fp2 = {
            let mut c2 = Coordinator::new();
            c2.compile_source(&src, "prop", &Default::default()).unwrap()
        };
        assert_eq!(fp, fp2, "fingerprint not deterministic for seed {seed}");
        fps.insert(fp);
    }
    assert!(fps.len() >= 38, "suspicious fingerprint collisions: {}", fps.len());
}
