//! End-to-end tests of the `repro serve` wire protocol against an
//! in-process daemon: concurrent multi-client runs must be bitwise
//! identical to serial in-process execution, saturation must shed load
//! with structured backpressure, deadlines must be honored, stale leases
//! must come back as re-bind errors, and malformed lines must never
//! wedge a connection.

use gt4rs::jsonw::{self, Value};
use gt4rs::serve::protocol::hex64;
use gt4rs::serve::{ServeConfig, Server};
use gt4rs::storage::{synthetic_fill, Storage};
use gt4rs::{Coordinator, ExecOptions, OptLevel};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// One NDJSON connection: send a line, read a line, parse it.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect to serve daemon");
        let writer = stream.try_clone().unwrap();
        Client { reader: BufReader::new(stream), writer }
    }

    fn request(&mut self, line: &str) -> Value {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        jsonw::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response `{resp}`: {e}"))
    }
}

fn ok(v: &Value) -> bool {
    v.get("ok").and_then(Value::as_bool) == Some(true)
}

fn code(v: &Value) -> Option<u64> {
    v.get("code").and_then(Value::as_u64)
}

/// `(name, sum_bits, hash)` digests from a run response.
fn response_digests(run: &Value) -> Vec<(String, String, String)> {
    run.get("fields")
        .and_then(Value::as_arr)
        .expect("run response has fields")
        .iter()
        .map(|f| {
            (
                f.get("name").unwrap().as_str().unwrap().to_string(),
                f.get("sum_bits").unwrap().as_str().unwrap().to_string(),
                f.get("hash").unwrap().as_str().unwrap().to_string(),
            )
        })
        .collect()
}

/// Serial in-process reference: same stencil, same domain, same
/// deterministic fill, same default scalars as the daemon's `bind`.
fn reference_digests(
    level: OptLevel,
    domain: [usize; 3],
    iters: u64,
) -> Vec<(String, String, String)> {
    let mut coord = Coordinator::new();
    coord.set_exec_options(ExecOptions::new().with_opt_level(level));
    let stencil = coord.stencil_library("hdiff", "vector").unwrap();
    let mut fields: Vec<(String, Storage)> = Vec::new();
    for (idx, f) in stencil.ir().fields.iter().enumerate() {
        let mut s = stencil.alloc_field(&f.name, domain).unwrap();
        synthetic_fill(&mut s, idx as f64);
        fields.push((f.name.clone(), s));
    }
    let scalars: Vec<(String, f64)> =
        stencil.ir().scalars.iter().map(|s| (s.name.clone(), 0.1)).collect();
    let mut inv = stencil
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(&scalars)
        .finish()
        .unwrap();
    for _ in 0..iters {
        let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
        inv.run(&mut refs).unwrap();
    }
    fields
        .iter()
        .map(|(n, s)| {
            (n.clone(), hex64(s.domain_sum().to_bits()), hex64(s.domain_hash()))
        })
        .collect()
}

const LEVELS: [OptLevel; 4] = [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];

/// Four concurrent clients, all tenants sharing one stencil library,
/// across O0–O3, on a domain small enough to ride the coalescer: every
/// wire digest must be bit-identical to the serial in-process reference.
#[test]
fn concurrent_clients_match_serial_in_process_bitwise() {
    let server = Server::spawn(ServeConfig::default()).unwrap();
    let addr = server.addr();
    const DOMAIN: [usize; 3] = [16, 16, 8]; // 2048 elems → coalesced path
    const ITERS: u64 = 3;

    let expected: Vec<_> =
        LEVELS.iter().map(|&l| reference_digests(l, DOMAIN, ITERS)).collect();

    let clients: Vec<_> = (0..4)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr);
                LEVELS
                    .iter()
                    .map(|level| {
                        let bind = client.request(&format!(
                            r#"{{"op":"bind","tenant":"soak","stencil":"hdiff","domain":[16,16,8],"options":{{"opt_level":"{level}"}},"id":{c}}}"#
                        ));
                        assert!(ok(&bind), "bind failed: {bind:?}");
                        let lease = bind.get("lease").unwrap().as_u64().unwrap();
                        let run = client.request(&format!(
                            r#"{{"op":"run","tenant":"soak","lease":{lease},"iters":{ITERS},"options":{{"threads":2}}}}"#
                        ));
                        assert!(ok(&run), "run failed: {run:?}");
                        response_digests(&run)
                    })
                    .collect::<Vec<_>>()
            })
        })
        .collect();

    for handle in clients {
        let per_client = handle.join().unwrap();
        for (got, want) in per_client.iter().zip(&expected) {
            assert_eq!(got, want, "wire digests diverged from serial reference");
        }
    }
}

/// The large-domain direct path (no coalescing) is bitwise identical too.
#[test]
fn direct_path_matches_serial_reference() {
    let server = Server::spawn(ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());
    const DOMAIN: [usize; 3] = [24, 20, 12]; // 5760 elems → direct path
    let expected = reference_digests(OptLevel::O2, DOMAIN, 2);
    let bind = client.request(
        r#"{"op":"bind","stencil":"hdiff","domain":[24,20,12],"options":{"opt_level":"2"}}"#,
    );
    assert!(ok(&bind), "{bind:?}");
    let lease = bind.get("lease").unwrap().as_u64().unwrap();
    let run = client.request(&format!(r#"{{"op":"run","lease":{lease},"iters":2}}"#));
    assert!(ok(&run), "{run:?}");
    assert_eq!(response_digests(&run), expected);
}

/// Over the wire, `options.dtype` salts the artifact fingerprint and
/// changes the run digests: an f32 lease never shares compiled stencils
/// — or bits — with an f64 lease of the same definition.
#[test]
fn wire_dtype_salts_fingerprints_and_digests() {
    let server = Server::spawn(ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());
    let mut runs = Vec::new();
    for dtype in ["f64", "f32"] {
        let bind = client.request(&format!(
            r#"{{"op":"bind","tenant":"prec","stencil":"hdiff","domain":[16,16,8],"options":{{"opt_level":"3","dtype":"{dtype}"}}}}"#
        ));
        assert!(ok(&bind), "{bind:?}");
        let fp = bind.get("fingerprint").unwrap().as_str().unwrap().to_string();
        let lease = bind.get("lease").unwrap().as_u64().unwrap();
        let run = client.request(&format!(
            r#"{{"op":"run","tenant":"prec","lease":{lease},"iters":2}}"#
        ));
        assert!(ok(&run), "{run:?}");
        runs.push((fp, response_digests(&run)));
    }
    let (fp64, digests64) = &runs[0];
    let (fp32, digests32) = &runs[1];
    assert_ne!(fp64, fp32, "dtype must salt the wire fingerprint");
    assert_ne!(
        digests64, digests32,
        "f32 digests bitwise-matched f64 — storage silently widened"
    );
}

/// Bind + start a long cheap-to-describe run that occupies the (single)
/// budget core; returns the join handle carrying the run response.
fn spawn_holder(addr: SocketAddr, iters: u64) -> std::thread::JoinHandle<Value> {
    std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        let bind = c.request(
            r#"{"op":"bind","tenant":"holder","stencil":"hdiff","domain":[64,64,32],"options":{"opt_level":"0"}}"#,
        );
        assert!(ok(&bind), "{bind:?}");
        let lease = bind.get("lease").unwrap().as_u64().unwrap();
        c.request(&format!(
            r#"{{"op":"run","tenant":"holder","lease":{lease},"iters":{iters},"deadline_ms":120000}}"#
        ))
    })
}

/// Poll `/metrics` until the core budget shows `want` cores in use.
fn wait_for_in_use(client: &mut Client, want: u64) {
    for _ in 0..5000 {
        let m = client.request(r#"{"op":"metrics"}"#);
        let text = m.get("text").unwrap().as_str().unwrap().to_string();
        if text.lines().any(|l| l == format!("serve_core_budget_in_use {want}")) {
            return;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("core budget never reached in_use {want}");
}

/// With one core and a zero-length wait queue, a second run is shed with
/// a structured 429 (code + retry hint), not queued into a blowup.
#[test]
fn saturation_sheds_load_with_structured_backpressure() {
    let config = ServeConfig {
        cores: 1,
        max_waiters: 0,
        small_domain_elems: 0, // coalescing off: every run is admitted directly
        ..ServeConfig::default()
    };
    let server = Server::spawn(config).unwrap();
    let addr = server.addr();
    let holder = spawn_holder(addr, 400);
    let mut probe = Client::connect(addr);
    wait_for_in_use(&mut probe, 1);

    let bind = probe.request(
        r#"{"op":"bind","tenant":"probe","stencil":"hdiff","domain":[16,16,8]}"#,
    );
    assert!(ok(&bind), "{bind:?}");
    let lease = bind.get("lease").unwrap().as_u64().unwrap();
    let shed = probe.request(&format!(
        r#"{{"op":"run","tenant":"probe","lease":{lease},"deadline_ms":30000}}"#
    ));
    assert!(!ok(&shed), "expected backpressure, got {shed:?}");
    assert_eq!(code(&shed), Some(429), "{shed:?}");
    assert!(shed.get("retry_after_ms").and_then(Value::as_u64).is_some(), "{shed:?}");
    assert!(
        shed.get("error").unwrap().as_str().unwrap().contains("saturated"),
        "{shed:?}"
    );

    assert!(ok(&holder.join().unwrap()), "holder run should have succeeded");

    // The shed request is visible in the metrics counters.
    let m = probe.request(r#"{"op":"metrics"}"#);
    let text = m.get("text").unwrap().as_str().unwrap().to_string();
    assert!(
        text.lines().any(|l| {
            l.starts_with("serve_backpressure_total ") && !l.ends_with(" 0")
        }),
        "{text}"
    );
}

/// A queued run whose deadline lapses while waiting for cores comes back
/// as a structured 408, and the wait queue drains.
#[test]
fn queued_run_times_out_at_its_deadline() {
    let config = ServeConfig {
        cores: 1,
        max_waiters: 8,
        small_domain_elems: 0,
        ..ServeConfig::default()
    };
    let server = Server::spawn(config).unwrap();
    let addr = server.addr();
    let holder = spawn_holder(addr, 400);
    let mut probe = Client::connect(addr);
    wait_for_in_use(&mut probe, 1);

    let bind = probe.request(
        r#"{"op":"bind","tenant":"probe","stencil":"hdiff","domain":[16,16,8]}"#,
    );
    assert!(ok(&bind), "{bind:?}");
    let lease = bind.get("lease").unwrap().as_u64().unwrap();
    let timed_out = probe.request(&format!(
        r#"{{"op":"run","tenant":"probe","lease":{lease},"deadline_ms":1}}"#
    ));
    assert!(!ok(&timed_out), "expected deadline error, got {timed_out:?}");
    assert_eq!(code(&timed_out), Some(408), "{timed_out:?}");

    assert!(ok(&holder.join().unwrap()));
}

/// Evicted leases produce 410 with a re-bind hint; never-issued lease ids
/// and unknown tenants produce 404.
#[test]
fn stale_and_unknown_leases_are_distinguished() {
    let config = ServeConfig { max_leases_per_tenant: 1, ..ServeConfig::default() };
    let server = Server::spawn(config).unwrap();
    let mut client = Client::connect(server.addr());

    let bind1 = client
        .request(r#"{"op":"bind","stencil":"hdiff","domain":[16,16,8]}"#);
    assert!(ok(&bind1), "{bind1:?}");
    let first = bind1.get("lease").unwrap().as_u64().unwrap();
    let bind2 = client
        .request(r#"{"op":"bind","stencil":"hdiff","domain":[16,16,8]}"#);
    assert!(ok(&bind2), "{bind2:?}");

    // The cap is 1, so the first lease was evicted: stale, re-bindable.
    let stale = client.request(&format!(r#"{{"op":"run","lease":{first}}}"#));
    assert_eq!(code(&stale), Some(410), "{stale:?}");
    assert!(stale.get("error").unwrap().as_str().unwrap().contains("re-bind"), "{stale:?}");

    // A lease id that was never issued is a plain 404.
    let unknown = client.request(r#"{"op":"run","lease":999}"#);
    assert_eq!(code(&unknown), Some(404), "{unknown:?}");

    // As is a tenant that never bound anything.
    let no_tenant = client.request(r#"{"op":"run","tenant":"ghost","lease":1}"#);
    assert_eq!(code(&no_tenant), Some(404), "{no_tenant:?}");
}

/// Malformed lines produce structured 400s and leave the connection
/// usable; request ids are echoed when recoverable.
#[test]
fn malformed_requests_do_not_wedge_the_connection() {
    let server = Server::spawn(ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());

    let garbage = client.request("this is not json");
    assert_eq!(code(&garbage), Some(400), "{garbage:?}");

    let unknown_field = client.request(r#"{"op":"metrics","wat":1}"#);
    assert_eq!(code(&unknown_field), Some(400), "{unknown_field:?}");

    let bad_op = client.request(r#"{"op":"frobnicate","id":7}"#);
    assert_eq!(code(&bad_op), Some(400), "{bad_op:?}");
    assert_eq!(bad_op.get("id").and_then(Value::as_u64), Some(7), "{bad_op:?}");

    // Compile without a stencil name: a handler-level 400.
    let no_stencil = client.request(r#"{"op":"compile"}"#);
    assert_eq!(code(&no_stencil), Some(400), "{no_stencil:?}");

    // The connection is still fine.
    let m = client.request(r#"{"op":"metrics"}"#);
    assert!(ok(&m), "{m:?}");
    assert!(m.get("text").unwrap().as_str().unwrap().contains("serve_requests_total"));
}

/// `compile` responses carry the opt-salted fingerprint: different opt
/// levels are different cache entries, same level is the same entry.
#[test]
fn compile_fingerprints_are_opt_salted_across_the_wire() {
    let server = Server::spawn(ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());
    let fp = |resp: &Value| resp.get("fingerprint").unwrap().as_str().unwrap().to_string();

    let o2a = client.request(r#"{"op":"compile","stencil":"hdiff"}"#);
    let o2b = client.request(r#"{"op":"compile","stencil":"hdiff"}"#);
    let o0 = client
        .request(r#"{"op":"compile","stencil":"hdiff","options":{"opt_level":0}}"#);
    assert!(ok(&o2a) && ok(&o2b) && ok(&o0));
    assert_eq!(fp(&o2a), fp(&o2b));
    assert_ne!(fp(&o2a), fp(&o0));
}

/// `/metrics` always exposes the persist counters; with a cache-dir
/// configured they actually move — a cold tenant misses and stores, a
/// second tenant compiling the same stencil hits the entries the first
/// one published.
#[test]
fn persist_counters_appear_in_metrics_and_move() {
    // Without a store: counters present, all zero.
    {
        let server = Server::spawn(ServeConfig::default()).unwrap();
        let mut client = Client::connect(server.addr());
        let m = client.request(r#"{"op":"metrics"}"#);
        let text = m.get("text").unwrap().as_str().unwrap().to_string();
        for line in ["persist_hits 0", "persist_misses 0", "persist_rejects 0"] {
            assert!(text.lines().any(|l| l == line), "missing `{line}` in:\n{text}");
        }
    }

    let dir = std::env::temp_dir()
        .join(format!("gt4rs_serve_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let config = ServeConfig {
        cache_dir: Some(dir.to_string_lossy().to_string()),
        ..ServeConfig::default()
    };
    let server = Server::spawn(config).unwrap();
    let mut client = Client::connect(server.addr());

    let metric = |client: &mut Client, name: &str| -> u64 {
        let m = client.request(r#"{"op":"metrics"}"#);
        let text = m.get("text").unwrap().as_str().unwrap().to_string();
        text.lines()
            .find_map(|l| l.strip_prefix(&format!("{name} ")))
            .unwrap_or_else(|| panic!("no `{name}` in:\n{text}"))
            .parse()
            .unwrap()
    };

    // Tenant A compiles cold: misses recorded, entries stored.
    let a = client.request(r#"{"op":"compile","tenant":"a","stencil":"hdiff"}"#);
    assert!(ok(&a), "{a:?}");
    assert!(metric(&mut client, "persist_misses") >= 1, "cold compile must miss");
    assert_eq!(metric(&mut client, "persist_hits"), 0);

    // Tenant B (fresh coordinator, same store) compiles the same stencil
    // at the same options: served from the store.
    let b = client.request(r#"{"op":"compile","tenant":"b","stencil":"hdiff"}"#);
    assert!(ok(&b), "{b:?}");
    assert_eq!(
        b.get("fingerprint").unwrap().as_str().unwrap(),
        a.get("fingerprint").unwrap().as_str().unwrap()
    );
    assert!(metric(&mut client, "persist_hits") >= 1, "warm compile must hit");
    assert_eq!(metric(&mut client, "persist_rejects"), 0);
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The shutdown op stops the accept loop (join returns), and the
/// response still makes it back to the requesting client.
#[test]
fn shutdown_op_stops_the_daemon() {
    let mut server = Server::spawn(ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr());
    let resp = client.request(r#"{"op":"shutdown"}"#);
    assert!(ok(&resp), "{resp:?}");
    assert_eq!(resp.get("stopping").and_then(Value::as_bool), Some(true));
    // Joins promptly because the op already poked the accept loop.
    server.shutdown();
}
