//! Cache-layer coverage: `PersistStore` on-disk persistence,
//! `StencilCache` hit/miss accounting through the coordinator, and the
//! fingerprint properties the caching design rests on — *invariant under
//! source reformatting, distinct across optimization levels*.

use gt4rs::analysis;
use gt4rs::cache::StencilCache;
use gt4rs::coordinator::Coordinator;
use gt4rs::opt::{OptConfig, OptLevel};
use gt4rs::persist::PersistStore;
use std::collections::BTreeMap;

/// Deterministic reformatting: inject whitespace/newlines around
/// punctuation without changing token structure.
fn reformat(src: &str, variant: u64) -> String {
    let mut out = String::with_capacity(src.len() * 2);
    let mut n = variant;
    for ch in src.chars() {
        out.push(ch);
        if matches!(ch, ';' | '{' | '}' | ',' | '(' | ')') {
            n = n.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            match (n >> 33) % 4 {
                0 => out.push(' '),
                1 => out.push('\n'),
                2 => out.push_str("  \n\t"),
                _ => {}
            }
        }
    }
    out
}

fn gen_source(seed: u64) -> String {
    // A small family of stencils exercising temporaries, builtins,
    // ternaries and offsets.
    let coef = 0.25 + (seed as f64) * 0.125;
    let off = 1 + (seed % 2) as i32;
    format!(
        "stencil fam(a: Field<f64>, out: Field<f64>; w: f64) {{\n\
           with computation(PARALLEL), interval(...) {{\n\
             t = a[{off},0,0] + a[-{off},0,0];\n\
             u = max(t * {coef:.3}, a) + sqrt(abs(t));\n\
             out = u > w ? u : w + t * {coef:.3};\n\
           }}\n\
         }}"
    )
}

#[test]
fn persist_store_roundtrip_and_isolation() {
    let dir = std::env::temp_dir().join(format!("gt4rs_dc_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = PersistStore::open(&dir).unwrap();
    assert!(cache.load("hlo", "0007").is_none());
    cache.store("hlo", "0007", "HloModule a").unwrap();
    cache.store("hlo", "0008", "HloModule b").unwrap();
    cache.store("ir", "0007", "{\"name\":\"x\"}").unwrap();
    assert_eq!(cache.load("hlo", "0007").unwrap(), "HloModule a");
    assert_eq!(cache.load("hlo", "0008").unwrap(), "HloModule b");
    assert_eq!(cache.load("ir", "0007").unwrap(), "{\"name\":\"x\"}");
    assert!(cache.load("hlo", "0009").is_none());
    // Overwrite is atomic-replace, last write wins.
    cache.store("hlo", "0007", "HloModule a2").unwrap();
    assert_eq!(cache.load("hlo", "0007").unwrap(), "HloModule a2");
    // Kinds are isolated per key; a second handle over the same
    // directory sees everything, counters start fresh per handle.
    let reopened = PersistStore::open(&dir).unwrap();
    assert_eq!(reopened.load("hlo", "0008").unwrap(), "HloModule b");
    assert_eq!(reopened.entries().len(), 3);
    assert_eq!(reopened.counters(), (1, 0, 0));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stencil_cache_counts_hits_and_misses() {
    let src = gen_source(0);
    let ir = analysis::compile_source(&src, "fam", &BTreeMap::new()).unwrap();
    let mut cache = StencilCache::new();
    assert!(cache.is_empty());
    cache.get_or_insert(ir.fingerprint, || Ok(ir.clone())).unwrap();
    for _ in 0..3 {
        cache
            .get_or_insert(ir.fingerprint, || panic!("must not recompile"))
            .unwrap();
    }
    assert_eq!((cache.hits, cache.misses, cache.len()), (3, 1, 1));
    // A failing compile is not memoized.
    let err = cache.get_or_insert(42, || Err(anyhow::anyhow!("boom")));
    assert!(err.is_err());
    assert_eq!(cache.len(), 1);
}

#[test]
fn coordinator_cache_hits_across_reformatting() {
    let mut coord = Coordinator::new();
    let src = gen_source(1);
    let fp = coord.compile_source(&src, "fam", &BTreeMap::new()).unwrap();
    for variant in 0..5 {
        let fp2 = coord
            .compile_source(&reformat(&src, variant), "fam", &BTreeMap::new())
            .unwrap();
        assert_eq!(fp, fp2, "variant {variant} missed the cache");
    }
    assert_eq!(coord.cache_stats(), (5, 1));
}

#[test]
fn fingerprint_invariant_under_reformatting_across_opt_levels() {
    for seed in 0..6u64 {
        let src = gen_source(seed);
        for level in [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3] {
            let config = OptConfig::level(level);
            let base = analysis::compile_source_opt(&src, "fam", &BTreeMap::new(), &config)
                .unwrap()
                .fingerprint;
            for variant in 0..4 {
                let alt = analysis::compile_source_opt(
                    &reformat(&src, seed * 31 + variant),
                    "fam",
                    &BTreeMap::new(),
                    &config,
                )
                .unwrap()
                .fingerprint;
                assert_eq!(
                    base, alt,
                    "seed {seed} O{level}: reformatting changed the fingerprint"
                );
            }
        }
    }
}

#[test]
fn fingerprint_changes_with_opt_level() {
    for seed in 0..6u64 {
        let src = gen_source(seed);
        let fp_at = |level: OptLevel| {
            analysis::compile_source_opt(&src, "fam", &BTreeMap::new(), &OptConfig::level(level))
                .unwrap()
                .fingerprint
        };
        let (f0, f1, f2, f3) = (
            fp_at(OptLevel::O0),
            fp_at(OptLevel::O1),
            fp_at(OptLevel::O2),
            fp_at(OptLevel::O3),
        );
        assert_ne!(f0, f1, "seed {seed}: O0 vs O1 fingerprints collide");
        assert_ne!(f1, f2, "seed {seed}: O1 vs O2 fingerprints collide");
        assert_ne!(f0, f2, "seed {seed}: O0 vs O2 fingerprints collide");
        // O3 runs the same passes as O2; only the fused execution strategy
        // differs — the opt tag must still separate the cache slots.
        assert_ne!(f2, f3, "seed {seed}: O2 vs O3 fingerprints collide");
        // Determinism at every level.
        assert_eq!(f2, fp_at(OptLevel::O2));
        assert_eq!(f3, fp_at(OptLevel::O3));
    }
}

#[test]
fn externals_and_structure_still_change_fingerprints() {
    // Guard against the opt-tag salting masking real identity changes.
    let src = "extern C = 1.0;\n\
               stencil s(a: Field<f64>, b: Field<f64>) {\n\
                 with computation(PARALLEL), interval(...) { b = a * C; }\n\
               }";
    let cfg = OptConfig::default();
    let f1 = analysis::compile_source_opt(src, "s", &BTreeMap::new(), &cfg)
        .unwrap()
        .fingerprint;
    let mut ov = BTreeMap::new();
    ov.insert("C".to_string(), 2.0);
    let f2 = analysis::compile_source_opt(src, "s", &ov, &cfg).unwrap().fingerprint;
    assert_ne!(f1, f2);
    let src3 = src.replace("a * C", "a + C");
    let f3 = analysis::compile_source_opt(&src3, "s", &BTreeMap::new(), &cfg)
        .unwrap()
        .fingerprint;
    assert_ne!(f1, f3);
}
