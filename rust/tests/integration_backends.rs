//! Cross-backend integration: every library stencil must produce the same
//! fields on every backend tier, including the JAX/Pallas AOT artifacts
//! (which require `make artifacts` — tests degrade to the available set
//! with a loud skip message if the artifact is missing).

use gt4rs::backend::pjrt_aot::PjrtAotBackend;
use gt4rs::coordinator::Coordinator;
use gt4rs::storage::Storage;

/// Domain for which `aot.py` always exports artifacts (TEST_DOMAINS).
const AOT_DOMAIN: [usize; 3] = [12, 10, 6];

fn fill(s: &mut Storage, seed: f64) {
    let [ni, nj, nk] = s.info.shape;
    let h = s.info.halo;
    for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
        for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
            for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                s.set(
                    i,
                    j,
                    k,
                    ((i as f64) * 0.31 + seed).sin() * ((j as f64) * 0.23 - seed).cos()
                        + 0.02 * k as f64,
                );
            }
        }
    }
}

/// Run `stencil` on `backend` via the handle API, returning the post-run
/// fields.
fn run_on(
    coord: &mut Coordinator,
    stencil: &str,
    backend: &str,
    domain: [usize; 3],
    scalars: &[(&str, f64)],
) -> anyhow::Result<Vec<(String, Storage)>> {
    let handle = coord.stencil_library(stencil, backend)?;
    let mut fields: Vec<(String, Storage)> = handle
        .ir()
        .fields
        .iter()
        .enumerate()
        .map(|(idx, f)| {
            let mut s = handle.alloc_field(&f.name, domain).unwrap();
            fill(&mut s, idx as f64);
            (f.name.clone(), s)
        })
        .collect();
    let mut inv = handle.bind().domain(domain).fields(&fields).scalars(scalars).finish()?;
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs)?;
    Ok(fields)
}

fn assert_all_backends_agree(stencil: &str, scalars: &[(&str, f64)], tol: f64) {
    let mut coord = Coordinator::new();
    let reference = run_on(&mut coord, stencil, "debug", AOT_DOMAIN, scalars).unwrap();
    for be in ["vector", "xla", "pjrt-aot"] {
        match run_on(&mut coord, stencil, be, AOT_DOMAIN, scalars) {
            Ok(fields) => {
                for ((n, r), (_, v)) in reference.iter().zip(&fields) {
                    let d = r.max_abs_diff(v);
                    assert!(
                        d <= tol,
                        "stencil `{stencil}` field `{n}`: {be} differs from debug by {d}"
                    );
                }
            }
            Err(e) if gt4rs::backend::is_unavailable(&e) => {
                eprintln!("SKIP {stencil} on {be}: backend unavailable (no PJRT runtime)");
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("make artifacts"),
                    "backend {be} failed for a non-artifact reason: {msg}"
                );
                eprintln!("SKIP {stencil} on {be}: artifact missing — run `make artifacts`");
            }
        }
    }
}

#[test]
fn hdiff_agrees_across_all_backends() {
    assert_all_backends_agree("hdiff", &[], 1e-12);
}

#[test]
fn vadv_agrees_across_all_backends() {
    assert_all_backends_agree("vadv", &[("dtdz", 0.3)], 1e-12);
}

#[test]
fn upwind_agrees_across_all_backends() {
    assert_all_backends_agree(
        "upwind_advect",
        &[("u", 0.8), ("v", -0.4), ("dtdx", 0.2), ("dtdy", 0.2)],
        1e-12,
    );
}

#[test]
fn figure1_diffusion_agrees_on_rust_backends() {
    // No AOT artifact for the Figure-1 stencil: debug/vector/xla only.
    let mut coord = Coordinator::new();
    let fp = coord
        .compile_source(gt4rs::stdlib::FIGURE1_SRC, "diffusion", &Default::default())
        .unwrap();
    let domain = AOT_DOMAIN;
    let xla_ok = gt4rs::runtime::pjrt_available();
    if !xla_ok {
        eprintln!("SKIP figure1 xla leg: PJRT runtime unavailable");
    }
    let backends: &[&str] = if xla_ok {
        &["debug", "vector", "xla"]
    } else {
        &["debug", "vector"]
    };
    let mut outs: Vec<Storage> = Vec::new();
    for be in backends {
        let handle = coord.stencil_for(fp, be).unwrap();
        let mut fields: Vec<(String, Storage)> = handle
            .ir()
            .fields
            .iter()
            .enumerate()
            .map(|(idx, f)| {
                let mut s = handle.alloc_field(&f.name, domain).unwrap();
                fill(&mut s, idx as f64);
                (f.name.clone(), s)
            })
            .collect();
        {
            let mut inv = handle
                .bind()
                .domain(domain)
                .scalar("alpha", 0.05)
                .fields(&fields)
                .finish()
                .unwrap();
            let mut refs: Vec<&mut Storage> =
                fields.iter_mut().map(|(_, s)| s).collect();
            inv.run(&mut refs).unwrap();
        }
        outs.push(fields.pop().unwrap().1);
    }
    assert!(outs[0].max_abs_diff(&outs[1]) == 0.0);
    if outs.len() > 2 {
        assert!(outs[0].max_abs_diff(&outs[2]) < 1e-12);
    }
}

#[test]
fn pallas_and_jnp_artifact_variants_agree() {
    if !gt4rs::runtime::pjrt_available() {
        eprintln!("SKIP pallas/jnp comparison: PJRT runtime unavailable");
        return;
    }
    let rt = gt4rs::runtime::Runtime::cpu().unwrap();
    let ir = gt4rs::stdlib::compile("hdiff").unwrap();
    let domain = AOT_DOMAIN;
    let mut results = Vec::new();
    for variant in ["pallas", "jnp"] {
        let be = PjrtAotBackend::with_runtime(rt.clone()).with_variant(variant);
        if !be.available(&format!("hdiff__{variant}"), domain) && !be.available("hdiff", domain)
        {
            eprintln!("SKIP pallas/jnp comparison: artifacts missing");
            return;
        }
        let mut fields: Vec<(String, Storage)> = ir
            .fields
            .iter()
            .enumerate()
            .map(|(idx, f)| {
                let e = f.extent;
                let mut s = Storage::zeros(gt4rs::storage::StorageInfo::new(
                    domain,
                    [
                        ((-e.i.0) as usize, e.i.1 as usize),
                        ((-e.j.0) as usize, e.j.1 as usize),
                        ((-e.k.0) as usize, e.k.1 as usize),
                    ],
                ));
                fill(&mut s, idx as f64);
                (f.name.clone(), s)
            })
            .collect();
        {
            let mut refs: Vec<(&str, &mut Storage)> =
                fields.iter_mut().map(|(n, s)| (n.as_str(), s)).collect();
            use gt4rs::backend::Backend;
            be.run(&ir, &mut gt4rs::backend::StencilArgs {
                fields: &mut refs,
                scalars: &[],
                domain,
            })
            .unwrap();
        }
        results.push(fields.pop().unwrap().1);
    }
    let d = results[0].max_abs_diff(&results[1]);
    assert!(d < 1e-12, "pallas vs jnp artifacts differ by {d}");
}

#[test]
fn chained_steps_accumulate_identically_across_backends() {
    // Multi-step integration: apply hdiff 5 times, feeding outputs back in.
    let mut coord = Coordinator::new();
    let fp = coord.compile_library("hdiff").unwrap();
    let domain = [16, 16, 8];
    let mut sums = Vec::new();
    let xla_ok = gt4rs::runtime::pjrt_available();
    let backends: &[&str] = if xla_ok {
        &["debug", "vector", "xla"]
    } else {
        eprintln!("SKIP chained xla leg: PJRT runtime unavailable");
        &["debug", "vector"]
    };
    for be in backends {
        let handle = coord.stencil_for(fp, be).unwrap();
        let mut inp = handle.alloc_field("in_phi", domain).unwrap();
        let mut coeff = handle.alloc_field("coeff", domain).unwrap();
        let mut out = handle.alloc_field("out_phi", domain).unwrap();
        fill(&mut inp, 0.0);
        coeff.fill(0.05);
        // Bind once; the five chained steps below are the run-many path.
        let mut inv = handle
            .bind()
            .field("in_phi", &inp)
            .field("coeff", &coeff)
            .field("out_phi", &out)
            .domain(domain)
            .finish()
            .unwrap();
        for _ in 0..5 {
            inv.run(&mut [&mut inp, &mut coeff, &mut out]).unwrap();
            // copy result back into the (halo'd) input, halo refreshed by
            // periodic wrap
            for i in 0..domain[0] as i64 {
                for j in 0..domain[1] as i64 {
                    for k in 0..domain[2] as i64 {
                        inp.set(i, j, k, out.get(i, j, k));
                    }
                }
            }
            gt4rs::model::periodic_halo_update(&mut inp);
        }
        sums.push(out.domain_sum());
    }
    assert!((sums[0] - sums[1]).abs() < 1e-9, "debug vs vector: {sums:?}");
    if sums.len() > 2 {
        assert!((sums[0] - sums[2]).abs() < 1e-9, "debug vs xla: {sums:?}");
    }
}
