//! Storage-layout equivalence: results must be identical whatever the
//! memory layout/alignment of the storages (the paper's backend-specific
//! storage customization must never change semantics, only speed).

use gt4rs::backend::{create, StencilArgs};
use gt4rs::storage::{Alignment, Layout, Storage, StorageInfo};
use gt4rs::stdlib;

fn make(layout: Layout, alignment: usize, domain: [usize; 3], halo: usize, seed: u64) -> Storage {
    let mut info = StorageInfo::new(domain, [(halo, halo), (halo, halo), (0, 0)]);
    info.layout = layout;
    info.alignment = Alignment(alignment);
    let mut s = Storage::zeros(info);
    let mut x = seed;
    let [ni, nj, nk] = domain;
    for i in -(halo as i64)..(ni + halo) as i64 {
        for j in -(halo as i64)..(nj + halo) as i64 {
            for k in 0..nk as i64 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                s.set(i, j, k, ((x >> 33) as f64) / (u32::MAX as f64) - 0.5);
            }
        }
    }
    s
}

fn run_hdiff(layout: Layout, alignment: usize, backend: &str) -> Storage {
    let domain = [10, 9, 5];
    let ir = stdlib::compile("hdiff").unwrap();
    let mut in_phi = make(layout, alignment, domain, 2, 1);
    let mut coeff = make(layout, alignment, domain, 2, 2);
    let mut out = make(layout, alignment, domain, 2, 3);
    let be = create(backend).unwrap();
    let mut refs: Vec<(&str, &mut Storage)> = vec![
        ("in_phi", &mut in_phi),
        ("coeff", &mut coeff),
        ("out_phi", &mut out),
    ];
    be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
        .unwrap();
    out
}

#[test]
fn hdiff_identical_across_layouts_and_alignments() {
    for backend in ["debug", "vector"] {
        let reference = run_hdiff(Layout::IJK, 1, backend);
        for layout in [Layout::IJK, Layout::KJI, Layout::JKI] {
            for alignment in [1usize, 4, 8, 16] {
                let got = run_hdiff(layout, alignment, backend);
                assert_eq!(
                    reference.max_abs_diff(&got),
                    0.0,
                    "{backend} differs for layout {layout} alignment {alignment}"
                );
            }
        }
    }
}

#[test]
fn sequential_stencil_identical_across_layouts() {
    let domain = [6, 5, 8];
    let ir = stdlib::compile("vadv").unwrap();
    let mut outs = Vec::new();
    for layout in [Layout::IJK, Layout::KJI, Layout::JKI] {
        let mut info = StorageInfo::new(domain, [(0, 0); 3]);
        info.layout = layout;
        let mut phi = Storage::zeros(info);
        let mut w = Storage::zeros(info);
        let [ni, nj, nk] = domain;
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    phi.set(i, j, k, (i + 2 * j) as f64 * 0.1 + k as f64 * 0.01);
                    w.set(i, j, k, ((i * j) % 3) as f64 * 0.2 - 0.1);
                }
            }
        }
        let be = create("vector").unwrap();
        let mut refs: Vec<(&str, &mut Storage)> = vec![("phi", &mut phi), ("w", &mut w)];
        be.run(&ir, &mut StencilArgs {
            fields: &mut refs,
            scalars: &[("dtdz", 0.3)],
            domain,
        })
        .unwrap();
        outs.push(phi);
    }
    assert_eq!(outs[0].max_abs_diff(&outs[1]), 0.0);
    assert_eq!(outs[0].max_abs_diff(&outs[2]), 0.0);
}

#[test]
fn cross_layout_arguments_mix_freely() {
    // Different fields of one call may use different layouts — a real
    // interop scenario (e.g. a KJI-optimized wind field feeding an IJK
    // tracer).
    let domain = [8, 8, 4];
    let ir = stdlib::compile("hdiff").unwrap();
    let mut in_phi = make(Layout::KJI, 8, domain, 2, 1);
    let mut coeff = make(Layout::JKI, 4, domain, 2, 2);
    let mut out = make(Layout::IJK, 1, domain, 2, 3);
    let be = create("vector").unwrap();
    {
        let mut refs: Vec<(&str, &mut Storage)> = vec![
            ("in_phi", &mut in_phi),
            ("coeff", &mut coeff),
            ("out_phi", &mut out),
        ];
        be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
            .unwrap();
    }
    // vs all-IJK reference with identical values
    let reference = {
        let mut ip = make(Layout::IJK, 1, domain, 2, 1);
        let mut cf = make(Layout::IJK, 1, domain, 2, 2);
        let mut o = make(Layout::IJK, 1, domain, 2, 3);
        let be = create("debug").unwrap();
        let mut refs: Vec<(&str, &mut Storage)> =
            vec![("in_phi", &mut ip), ("coeff", &mut cf), ("out_phi", &mut o)];
        be.run(&ir, &mut StencilArgs { fields: &mut refs, scalars: &[], domain })
            .unwrap();
        o
    };
    assert_eq!(reference.max_abs_diff(&out), 0.0);
}
