//! Warm-start equivalence: compile → persist → drop the coordinator →
//! reload in a fresh coordinator from the same cache root. The reloaded
//! artifacts must be *indistinguishable* from fresh compiles — identical
//! fingerprints, identical canonical IR text, bitwise-identical run
//! results at every opt level × executor tier × sharding plan — and the
//! fresh coordinator must get there with **zero** dsl→analysis→opt
//! pipeline runs (the `pipeline_compiles` honesty counter).

use gt4rs::coordinator::Coordinator;
use gt4rs::ir::canon;
use gt4rs::opt::{ExecOptions, OptLevel};
use gt4rs::persist::PersistStore;
use gt4rs::storage::{synthetic_fill, Storage};
use gt4rs::{ExecTier, Sharding};
use std::sync::Arc;

const STENCILS: [&str; 3] = ["hdiff", "vadv", "diffuse"];
const LEVELS: [OptLevel; 4] = [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];
/// Scheduling combos every warm artifact must agree with its cold twin
/// on. Tiers only differentiate at O3; running them everywhere is a
/// free no-op elsewhere.
const SCHEDULES: [(ExecTier, Sharding); 3] = [
    (ExecTier::Interpreted, Sharding::Off),
    (ExecTier::Specialized, Sharding::Off),
    (ExecTier::Specialized, Sharding::Threads(2)),
];

fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("gt4rs_ws_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn coordinator(level: OptLevel, store: &Arc<PersistStore>) -> Coordinator {
    let mut c = Coordinator::new();
    c.set_exec_options(ExecOptions::new().with_opt_level(level));
    c.set_persist(store.clone());
    c
}

/// Run `fp` on the vector backend under one schedule; returns
/// `(name, sum_bits, hash)` digests in declaration order.
fn run_digests(
    coord: &mut Coordinator,
    fp: u64,
    tier: ExecTier,
    sharding: Sharding,
) -> Vec<(String, u64, u64)> {
    let stencil = coord.stencil_for(fp, "vector").unwrap();
    let domain = [10, 9, 6];
    let mut fields: Vec<(String, Storage)> = Vec::new();
    for (idx, f) in stencil.ir().fields.iter().enumerate() {
        let mut s = stencil.alloc_field(&f.name, domain).unwrap();
        synthetic_fill(&mut s, idx as f64);
        fields.push((f.name.clone(), s));
    }
    let scalars: Vec<(String, f64)> =
        stencil.ir().scalars.iter().map(|s| (s.name.clone(), 0.1)).collect();
    let mut inv = stencil
        .bind()
        .domain(domain)
        .fields(&fields)
        .scalars(&scalars)
        .finish()
        .unwrap();
    inv.set_exec_tier(tier);
    inv.set_sharding(sharding);
    let mut refs: Vec<&mut Storage> = fields.iter_mut().map(|(_, s)| s).collect();
    inv.run(&mut refs).unwrap();
    fields
        .iter()
        .map(|(n, s)| (n.clone(), s.domain_sum().to_bits(), s.domain_hash()))
        .collect()
}

#[test]
fn warm_start_is_bitwise_identical_and_pipeline_free() {
    let dir = scratch_dir("equiv");
    for level in LEVELS {
        // --- Cold pass: compile through the pipeline, store-through. ---
        let store = Arc::new(PersistStore::open(&dir).unwrap());
        let mut cold = coordinator(level, &store);
        let mut expected = Vec::new();
        for name in STENCILS {
            let fp = cold.compile_library(name).unwrap();
            let ir = cold.ir(fp).unwrap();
            let tag = cold.opt_config().canon();
            let canon_text = canon::canon_ir(&ir, &tag);
            let mut runs = Vec::new();
            for (tier, sharding) in SCHEDULES {
                runs.push(run_digests(&mut cold, fp, tier, sharding));
            }
            expected.push((name, fp, ir.fingerprint, canon_text, runs));
        }
        assert_eq!(
            cold.pipeline_compiles(),
            STENCILS.len() as u64,
            "O{level}: cold pass must run the pipeline once per stencil"
        );
        drop(cold);
        drop(store);

        // --- Warm pass: fresh coordinator + fresh store handle, same
        // root. Everything must come back from disk. ---
        let store = Arc::new(PersistStore::open(&dir).unwrap());
        let mut warm = coordinator(level, &store);
        for (name, fp, ir_fp, canon_text, runs) in &expected {
            let fp2 = warm.compile_library(name).unwrap();
            assert_eq!(fp2, *fp, "O{level} {name}: warm cache key diverged");
            let ir = warm.ir(fp2).unwrap();
            assert_eq!(ir.fingerprint, *ir_fp, "O{level} {name}: IR fingerprint diverged");
            let tag = warm.opt_config().canon();
            assert_eq!(
                &canon::canon_ir(&ir, &tag),
                canon_text,
                "O{level} {name}: canonical IR text diverged after reload"
            );
            for ((tier, sharding), cold_digests) in SCHEDULES.iter().zip(runs) {
                let warm_digests = run_digests(&mut warm, fp2, *tier, *sharding);
                assert_eq!(
                    &warm_digests, cold_digests,
                    "O{level} {name} {tier:?}/{sharding:?}: warm run not bitwise-identical"
                );
            }
        }
        assert_eq!(
            warm.pipeline_compiles(),
            0,
            "O{level}: warm pass must not run the pipeline at all"
        );
        let (hits, _misses, rejects) = warm.persist_counters().unwrap();
        assert!(hits > 0, "O{level}: warm pass must load from the store");
        assert_eq!(rejects, 0, "O{level}: warm pass rejected valid entries");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn f32_artifacts_warm_start_and_never_alias_f64_entries() {
    use gt4rs::dsl::ast::DType;
    let dir = scratch_dir("f32");
    // --- Cold pass at both precisions through one store. ---
    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut cold = coordinator(OptLevel::O3, &store);
    let fp64 = cold.compile_library("hdiff").unwrap();
    cold.set_dtype(Some(DType::F32));
    let fp32 = cold.compile_library("hdiff").unwrap();
    assert_ne!(fp32, fp64, "f32 and f64 artifacts must have distinct fingerprints");
    let keys: Vec<String> = store
        .entries()
        .iter()
        .filter(|e| e.kind == "ir")
        .map(|e| e.key.clone())
        .collect();
    assert!(keys.contains(&format!("{fp32:016x}")));
    assert!(keys.contains(&format!("{fp64:016x}")), "distinct persist entries required");
    let digests32 = run_digests(&mut cold, fp32, ExecTier::Specialized, Sharding::Off);
    drop(cold);
    drop(store);

    // --- Warm pass at f32: pipeline-free, bitwise-identical. ---
    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut warm = coordinator(OptLevel::O3, &store);
    warm.set_dtype(Some(DType::F32));
    let fp = warm.compile_library("hdiff").unwrap();
    assert_eq!(fp, fp32);
    assert_eq!(warm.pipeline_compiles(), 0, "f32 warm start must skip the pipeline");
    let ir = warm.ir(fp).unwrap();
    assert_eq!(ir.dtype(), DType::F32, "reloaded artifact lost its element type");
    let warm32 = run_digests(&mut warm, fp, ExecTier::Specialized, Sharding::Off);
    assert_eq!(warm32, digests32, "f32 warm run not bitwise-identical");
    drop(warm);
    drop(store);

    // --- Dtype skew is a miss: a store holding only f32 entries must
    // not satisfy an f64 compile (and vice versa — the fingerprints
    // simply never collide). ---
    let skew_dir = scratch_dir("f32skew");
    let store = Arc::new(PersistStore::open(&skew_dir).unwrap());
    let mut c = coordinator(OptLevel::O3, &store);
    c.set_dtype(Some(DType::F32));
    c.compile_library("hdiff").unwrap();
    drop(c);
    drop(store);
    let store = Arc::new(PersistStore::open(&skew_dir).unwrap());
    let mut c = coordinator(OptLevel::O3, &store);
    let fp = c.compile_library("hdiff").unwrap();
    assert_eq!(fp, fp64);
    assert_eq!(
        c.pipeline_compiles(),
        1,
        "an f64 compile must treat a dtype-skewed (f32-only) store as cold"
    );
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&skew_dir);
}

#[test]
fn corrupted_ir_entry_is_rejected_and_recompiled() {
    let dir = scratch_dir("reject");
    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut cold = coordinator(OptLevel::O2, &store);
    let fp = cold.compile_library("hdiff").unwrap();
    let sum_cold = run_digests(&mut cold, fp, ExecTier::Specialized, Sharding::Off);
    drop(cold);
    // Replace the IR entry with a digest-valid envelope whose payload is
    // not a deserializable IR: the loader must demote the hit to a
    // reject and silently fall back to the pipeline.
    store.store("ir", &format!("{fp:016x}"), "{\"not\":\"an ir\"}").unwrap();
    drop(store);

    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut warm = coordinator(OptLevel::O2, &store);
    let fp2 = warm.compile_library("hdiff").unwrap();
    assert_eq!(fp2, fp);
    assert_eq!(warm.pipeline_compiles(), 1, "corrupt entry must force a recompile");
    let (_, _, rejects) = warm.persist_counters().unwrap();
    assert_eq!(rejects, 1, "semantic corruption must count as a reject");
    // The recompile stored a good entry back; results are unaffected.
    let sum_warm = run_digests(&mut warm, fp2, ExecTier::Specialized, Sharding::Off);
    assert_eq!(sum_warm, sum_cold);
    drop(warm);
    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut again = coordinator(OptLevel::O2, &store);
    again.compile_library("hdiff").unwrap();
    assert_eq!(again.pipeline_compiles(), 0, "repaired entry must load cleanly");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_tool_or_schema_recompiles_without_error() {
    // A store written by "another toolchain" (entries whose tool tag
    // differs) must behave exactly like an empty store.
    let dir = scratch_dir("skew");
    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut cold = coordinator(OptLevel::O3, &store);
    let fp = cold.compile_library("diffuse").unwrap();
    drop(cold);
    // Rewrite every entry's tool tag in place (digest untouched — the
    // tool check fires first and classifies the entry as a plain miss).
    for e in store.entries() {
        let path = dir.join(format!("{}_{}.json", e.kind, e.key));
        let text = std::fs::read_to_string(&path).unwrap();
        let skewed = text.replace(
            &format!("\"tool\":\"{}\"", env!("CARGO_PKG_VERSION")),
            "\"tool\":\"0.0.0-other\"",
        );
        assert_ne!(text, skewed, "test must actually rewrite the tool tag");
        std::fs::write(&path, skewed).unwrap();
    }
    drop(store);
    let store = Arc::new(PersistStore::open(&dir).unwrap());
    let mut warm = coordinator(OptLevel::O3, &store);
    let fp2 = warm.compile_library("diffuse").unwrap();
    assert_eq!(fp2, fp);
    assert_eq!(warm.pipeline_compiles(), 1, "skewed entries must recompile");
    let (hits, misses, rejects) = warm.persist_counters().unwrap();
    assert_eq!(hits, 0);
    assert!(misses >= 1);
    assert_eq!(rejects, 0, "version skew is a miss, never a reject");
    let _ = std::fs::remove_dir_all(&dir);
}
