//! Quickstart: define a stencil in GTScript-RS, compile it to a
//! first-class `Stencil` handle, bind its arguments **once**, run it
//! many times, fan the same compiled handle out across threads, split a
//! *single call* across cores with intra-call domain sharding,
//! warm-start a fresh coordinator from the on-disk artifact store, and
//! re-run the whole program at f32 to measure what the narrower storage
//! costs in roundoff — the 60-second tour of the framework.
//!
//!     cargo run --release --example quickstart
//!
//! (On the CLI the sharding knob is `repro run ... --threads N|auto|off`,
//! or the `REPRO_THREADS` environment variable.)

use anyhow::Result;
use gt4rs::coordinator::Coordinator;
use gt4rs::storage::Storage;
use gt4rs::{ExecOptions, ExecTier, OptLevel, Sharding};

const SRC: &str = "
    # A smoothing stencil: out = (1-w)*phi + w/4 * neighbor-average
    stencil smooth(phi: Field<f64>, out: Field<f64>; w: f64) {
        with computation(PARALLEL), interval(...) {
            avg = (phi[-1,0,0] + phi[1,0,0] + phi[0,-1,0] + phi[0,1,0]) * 0.25;
            out = (1.0 - w) * phi + w * avg;
        }
    }";

fn fill(phi: &mut Storage) {
    let h = phi.info.halo;
    let [ni, nj, nk] = phi.info.shape;
    for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
        for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
            for k in 0..nk as i64 {
                phi.set(i, j, k, (i as f64 * 0.3).sin() + (j as f64 * 0.2).cos());
            }
        }
    }
}

fn main() -> Result<()> {
    let mut coord = Coordinator::new();

    // 1. Compile: parse -> inline -> resolve -> lower -> checks -> extents
    //    -> optimizer. The result is a cheap-to-clone, Send + Sync handle
    //    sharing the cached IR with the coordinator (the GT4Py
    //    `gtscript.stencil(backend=...)` return value).
    let stencil = coord.stencil(SRC, "smooth", "vector", &Default::default())?;
    println!("=== implementation IR ===\n{}", stencil.ir().dump());

    // 2. Allocate storages with exactly the halos the analysis derived
    //    (the paper's backend-aware `storage` containers).
    let domain = [16, 16, 4];
    let mut phi = stencil.alloc_field("phi", domain)?;
    let mut out = stencil.alloc_field("out", domain)?;
    fill(&mut phi);

    // 3. Bind once: the full layout/halo/dtype validation — the paper's
    //    Fig. 3 constant per-call overhead — happens exactly here.
    let mut step = stencil
        .bind()
        .field("phi", &phi)
        .field("out", &out)
        .scalar("w", 0.5)
        .domain(domain)
        .finish()?;

    // 4. Run many: repeat calls only re-check shapes. The first call's
    //    stats carry the bind-time validation; watch the checks column
    //    collapse afterwards.
    for round in 0..3 {
        let stats = step.run(&mut [&mut phi, &mut out])?;
        println!(
            "vector run {round}: execute {:?}  checks {:?}{}",
            stats.execute,
            stats.checks,
            if round == 0 { "  (includes the one-time full validation)" } else { "" }
        );
    }
    let sum_vector = out.domain_sum();

    // 5. The debug backend is the bit-exact reference interpreter.
    let reference = coord.stencil(SRC, "smooth", "debug", &Default::default())?;
    let mut rphi = reference.alloc_field("phi", domain)?;
    let mut rout = reference.alloc_field("out", domain)?;
    fill(&mut rphi);
    reference
        .bind()
        .field("phi", &rphi)
        .field("out", &rout)
        .scalar("w", 0.5)
        .domain(domain)
        .finish()?
        .run(&mut [&mut rphi, &mut rout])?;
    assert_eq!(out.max_abs_diff(&rout), 0.0, "vector must match debug bitwise");

    // 6. Concurrent dispatch: clone the handle into threads; every clone
    //    shares the same compiled artifact and backend instance.
    let sums: Vec<f64> = std::thread::scope(|s| {
        let joins: Vec<_> = (0..4)
            .map(|_| {
                let h = stencil.clone();
                s.spawn(move || {
                    let mut phi = h.alloc_field("phi", domain).unwrap();
                    let mut out = h.alloc_field("out", domain).unwrap();
                    fill(&mut phi);
                    let mut inv = h
                        .bind()
                        .field("phi", &phi)
                        .field("out", &out)
                        .scalar("w", 0.5)
                        .domain(domain)
                        .finish()
                        .unwrap();
                    inv.run(&mut [&mut phi, &mut out]).unwrap();
                    out.domain_sum()
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    for s in &sums {
        assert_eq!(s.to_bits(), sum_vector.to_bits(), "concurrent run diverged");
    }
    println!("4 concurrent clones agree bitwise: checksum {sum_vector:.12e}");

    // 7. Intra-call domain sharding: one invocation's compute domain
    //    split into halo-correct i-slabs on a persistent worker pool.
    //    Purely a scheduling knob — the result is bitwise identical to
    //    the serial run, and RunStats reports the thread count actually
    //    used (an `Auto` plan degrades to serial on tiny domains).
    let mut sphi = stencil.alloc_field("phi", domain)?;
    let mut sout = stencil.alloc_field("out", domain)?;
    fill(&mut sphi);
    let mut sharded = stencil
        .bind()
        .field("phi", &sphi)
        .field("out", &sout)
        .scalar("w", 0.5)
        .domain(domain)
        .sharding(Sharding::Threads(2))
        .finish()?;
    for round in 0..3 {
        let stats = sharded.run(&mut [&mut sphi, &mut sout])?;
        println!(
            "sharded run {round}: execute {:?}  threads used {}",
            stats.execute,
            stats.threads_used()
        );
    }
    assert_eq!(
        sout.domain_sum().to_bits(),
        sum_vector.to_bits(),
        "sharded run must be bitwise identical to serial"
    );

    // 8. Executor tiers: at `--opt-level 3` the fused evaluator lowers
    //    each fusion group's tape into a specialized kernel plan (dense
    //    slot tables, hoisted bounds guards, cache-blocked interior) —
    //    the default executor. `ExecTier::Interpreted` walks the same
    //    tape op by op. Both are bitwise identical by contract, so the
    //    tier is a per-invocation scheduling knob exactly like sharding.
    //    (Opt-in fast-math relaxation is deliberately *not* a scheduling
    //    knob: it salts the fingerprint and is only tolerance-equal —
    //    see `repro run --fast-math`.) All four execution knobs travel as
    //    one `ExecOptions` value — the same surface the CLI flags and the
    //    serve wire protocol parse into.
    coord.set_exec_options(ExecOptions::new().with_opt_level(OptLevel::O3));
    let fused = coord.stencil(SRC, "smooth", "vector", &Default::default())?;
    let mut fphi = fused.alloc_field("phi", domain)?;
    let mut fout = fused.alloc_field("out", domain)?;
    fill(&mut fphi);
    for tier in [ExecTier::Specialized, ExecTier::Interpreted] {
        let mut inv = fused
            .bind()
            .field("phi", &fphi)
            .field("out", &fout)
            .scalar("w", 0.5)
            .domain(domain)
            .exec_tier(tier)
            .finish()?;
        let stats = inv.run(&mut [&mut fphi, &mut fout])?;
        println!("O3 {tier} run: execute {:?}", stats.execute);
        assert_eq!(
            fout.domain_sum().to_bits(),
            sum_vector.to_bits(),
            "executor tiers must agree bitwise (and match every opt level)"
        );
    }

    // 9. The XLA JIT backend, when a PJRT runtime is present.
    match coord.stencil(SRC, "smooth", "xla", &Default::default()) {
        Ok(xla) => {
            let mut xphi = xla.alloc_field("phi", domain)?;
            let mut xout = xla.alloc_field("out", domain)?;
            fill(&mut xphi);
            let mut inv = xla
                .bind()
                .field("phi", &xphi)
                .field("out", &xout)
                .scalar("w", 0.5)
                .domain(domain)
                .finish()?;
            for round in 0..2 {
                let stats = inv.run(&mut [&mut xphi, &mut xout])?;
                println!(
                    "xla run ({}): {:?}",
                    if round == 0 { "compile+run" } else { "cached" },
                    stats.execute
                );
            }
            assert!((xout.domain_sum() - sum_vector).abs() < 1e-9);
        }
        Err(e) if gt4rs::backend::is_unavailable(&e) => {
            println!("xla backend unavailable (no PJRT runtime) — skipped");
        }
        Err(e) => return Err(e),
    }

    // 10. Stencils as a service: spawn the `repro serve` daemon
    //     in-process, round-trip the same stencil over its
    //     newline-delimited JSON protocol, and check the wire digest
    //     against the in-process result — bit-exact, because the daemon
    //     allocates with the same deterministic `synthetic_fill` and the
    //     options crossing the wire are the same `ExecOptions` surface.
    //     (Stand-alone: `repro serve --addr 127.0.0.1:7070`, then
    //     `repro client --addr 127.0.0.1:7070 --request '{"op":...}'`.)
    {
        use gt4rs::jsonw::{self, Obj, Value};
        use gt4rs::serve::{ServeConfig, Server};
        use std::io::{BufRead, BufReader, Write};

        let mut server = Server::spawn(ServeConfig::default())?;
        let stream = std::net::TcpStream::connect(server.addr())?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        let mut round_trip = |line: String| -> Result<Value> {
            writer.write_all(line.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            let mut resp = String::new();
            reader.read_line(&mut resp)?;
            jsonw::parse(resp.trim()).map_err(|e| anyhow::anyhow!("bad response: {e}"))
        };

        let bind = round_trip(
            Obj::new()
                .str("op", "bind")
                .str("stencil", "smooth")
                .str("src", SRC)
                .raw("domain", "[16,16,4]")
                .raw("scalars", "{\"w\":0.5}")
                .raw("options", "{\"opt_level\":\"2\"}")
                .finish(),
        )?;
        assert_eq!(bind.get("ok").and_then(Value::as_bool), Some(true));
        let lease = bind.get("lease").and_then(Value::as_u64).unwrap();
        let run = round_trip(format!("{{\"op\":\"run\",\"lease\":{lease}}}"))?;
        assert_eq!(run.get("ok").and_then(Value::as_bool), Some(true));
        let wire_hash = run
            .get("fields")
            .and_then(Value::as_arr)
            .and_then(|fields| {
                fields.iter().find(|f| {
                    f.get("name").and_then(Value::as_str) == Some("out")
                })
            })
            .and_then(|f| f.get("hash").and_then(Value::as_str))
            .unwrap()
            .to_string();

        // The same single run, in-process, from the same synthetic fill.
        let mut wphi = stencil.alloc_field("phi", domain)?;
        let mut wout = stencil.alloc_field("out", domain)?;
        gt4rs::storage::synthetic_fill(&mut wphi, 0.0);
        gt4rs::storage::synthetic_fill(&mut wout, 1.0);
        stencil
            .bind()
            .field("phi", &wphi)
            .field("out", &wout)
            .scalar("w", 0.5)
            .domain(domain)
            .finish()?
            .run(&mut [&mut wphi, &mut wout])?;
        let local_hash = format!("{:016x}", wout.domain_hash());
        assert_eq!(wire_hash, local_hash, "wire run must match in-process bitwise");
        println!("serve round-trip agrees bitwise: hash {wire_hash}");
        server.shutdown();
    }

    // 11. Warm start: attach a persistent artifact store and the
    //     compiled stencil survives the "process" (played here by a
    //     brand-new coordinator). The reload runs **zero**
    //     dsl→analysis→opt pipelines — the `pipeline_compiles` counter
    //     proves it — and is bitwise-identical to the fresh compile.
    //     Across real processes this is `repro warm --cache-dir DIR`
    //     followed by `repro run ... --cache-dir DIR` (or the
    //     `REPRO_CACHE_DIR` environment variable).
    {
        use gt4rs::persist::PersistStore;
        use std::sync::Arc;

        let dir = std::env::temp_dir().join(format!("gt4rs_quickstart_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        let mut first = Coordinator::new();
        first.set_persist(Arc::new(PersistStore::open(&dir)?));
        first.stencil(SRC, "smooth", "vector", &Default::default())?;
        assert_eq!(first.pipeline_compiles(), 1);
        drop(first);

        let mut fresh = Coordinator::new();
        fresh.set_persist(Arc::new(PersistStore::open(&dir)?));
        let warm = fresh.stencil(SRC, "smooth", "vector", &Default::default())?;
        assert_eq!(fresh.pipeline_compiles(), 0, "warm start must skip the pipeline");
        let mut pphi = warm.alloc_field("phi", domain)?;
        let mut pout = warm.alloc_field("out", domain)?;
        fill(&mut pphi);
        warm.bind()
            .field("phi", &pphi)
            .field("out", &pout)
            .scalar("w", 0.5)
            .domain(domain)
            .finish()?
            .run(&mut [&mut pphi, &mut pout])?;
        assert_eq!(
            pout.domain_sum().to_bits(),
            sum_vector.to_bits(),
            "warm-started stencil must match the fresh compile bitwise"
        );
        println!("warm start from disk: 0 pipeline runs, checksum matches bitwise");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // 12. Precision: `ExecOptions::with_dtype` retypes the whole program
    //     — every field, scalar slot and temporary — so storage, tapes
    //     and kernel plans all run genuinely at f32. The dtype salts the
    //     fingerprint (an f32 artifact never shadows an f64 one in any
    //     cache), and each dtype is bitwise-reproducible against its own
    //     debug interpreter; *across* dtypes the difference is real
    //     roundoff, which we report as a relative L2 norm. On the CLI
    //     this is `repro run ... --dtype f32` and
    //     `repro model --precision-sweep`.
    {
        use gt4rs::dsl::ast::DType;

        let mut prec = Coordinator::new();
        prec.set_exec_options(ExecOptions::new().with_opt_level(OptLevel::O3));
        let run_at = |coord: &mut Coordinator, dtype: Option<DType>| -> Result<(u64, Storage)> {
            coord.set_dtype(dtype);
            let handle = coord.stencil(SRC, "smooth", "vector", &Default::default())?;
            let mut phi = handle.alloc_field("phi", domain)?;
            let mut out = handle.alloc_field("out", domain)?;
            fill(&mut phi); // f64 facade: values round on the way into f32 storage
            handle
                .bind()
                .field("phi", &phi)
                .field("out", &out)
                .scalar("w", 0.5)
                .domain(domain)
                .finish()?
                .run(&mut [&mut phi, &mut out])?;
            Ok((handle.fingerprint(), out))
        };
        let (fp64, out64) = run_at(&mut prec, None)?;
        let (fp32, out32) = run_at(&mut prec, Some(DType::F32))?;
        assert_ne!(fp64, fp32, "dtype must salt the fingerprint");
        assert_eq!(out32.dtype(), DType::F32);
        let rel = out32.rel_l2_error(&out64);
        assert!(rel > 0.0, "f32 bitwise-matched f64 — storage silently widened");
        assert!(rel < 1e-5, "one smoothing step should stay near f32 epsilon");
        println!(
            "f32 vs f64: fingerprints {fp64:016x} / {fp32:016x}, rel_l2 {rel:.3e}"
        );
    }

    println!("quickstart OK");
    Ok(())
}
