//! End-to-end driver (DESIGN.md §E2E): the isentropic-like model — the
//! paper's Tasmania analog — run on a real small workload, proving all
//! layers compose: GTScript-RS sources → analysis pipeline → backends
//! (including the JAX/Pallas AOT tier) inside a multi-stencil time loop
//! with boundary conditions and conservation diagnostics. The driver
//! binds its three stencil invocations once at construction and reuses
//! them every step (bind-once/run-many).
//!
//!     cargo run --release --example isentropic_model [steps] [backend]
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use gt4rs::model::{IsentropicModel, ModelConfig};
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let backend = args.get(1).cloned().unwrap_or_else(|| "vector".to_string());

    let config = ModelConfig {
        domain: [48, 48, 16],
        u: 1.2,
        v: 0.7,
        w_amp: 0.1,
        diffusion_coeff: 0.03,
        dt: 0.15,
        backend: backend.clone(),
        ..ModelConfig::default()
    };
    println!(
        "# isentropic-like model | domain {:?} | backend {} | {} steps",
        config.domain, backend, steps
    );
    let mut model = IsentropicModel::new(config)?;

    let mass0 = model.phi_snapshot().domain_sum();
    println!("{:>6} {:>16} {:>12} {:>12} {:>10}", "step", "mass", "min", "max", "wall");
    let t0 = Instant::now();
    let mut last = None;
    for s in 1..=steps {
        let d = model.step()?;
        if s % (steps / 15).max(1) == 0 || s == steps {
            println!(
                "{:>6} {:>16.9e} {:>12.4e} {:>12.4e} {:>10?}",
                d.step, d.mass, d.min, d.max, d.wall
            );
        }
        last = Some(d);
    }
    let total = t0.elapsed();
    let d = last.unwrap();
    let drift = ((d.mass - mass0) / mass0).abs();

    println!("\n=== summary ===");
    println!("steps/s          : {:.2}", steps as f64 / total.as_secs_f64());
    println!("total wall       : {total:?}");
    println!("mass drift       : {:.3e} (relative)", drift);
    println!("field bounds     : [{:.4e}, {:.4e}]", d.min, d.max);
    assert!(d.max.is_finite() && d.max < 10.0, "model blew up");
    assert!(drift < 0.2, "mass drift too large: {drift}");
    println!("isentropic_model OK");
    Ok(())
}
