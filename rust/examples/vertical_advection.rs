//! The paper's Figure 3 (right) workload: implicit vertical advection.
//!
//! Demonstrates the sequential `computation(FORWARD/BACKWARD)` machinery:
//! a Thomas solve per column, validated against the hand-written native
//! solver and across backends (via `Stencil` handles), plus a physical
//! sanity check (advection of a vertical profile by a constant updraft).
//!
//!     cargo run --release --example vertical_advection

use anyhow::Result;
use gt4rs::baseline;
use gt4rs::coordinator::{Coordinator, Stencil};
use gt4rs::storage::Storage;

fn main() -> Result<()> {
    let mut coord = Coordinator::new();
    let domain = [48, 48, 24]; // an AOT artifact exists for this domain
    let fp = coord.compile_library("vadv")?;
    let dtdz = 0.3;

    let make_fields = |stencil: &Stencil| -> Result<(Storage, Storage)> {
        let mut phi = stencil.alloc_field("phi", domain)?;
        let mut w = stencil.alloc_field("w", domain)?;
        let [ni, nj, nk] = domain;
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    // Gaussian pulse in the vertical, varying per column.
                    let z = k as f64 - nk as f64 / 3.0;
                    phi.set(i, j, k, (-z * z / 18.0).exp() + 0.01 * (i + j) as f64);
                    w.set(i, j, k, 0.8 + 0.1 * ((i * 7 + j * 3) % 5) as f64);
                }
            }
        }
        Ok((phi, w))
    };

    // Native reference.
    let reference_stencil = coord.stencil_for(fp, "debug")?;
    let (mut phi_ref, w) = make_fields(&reference_stencil)?;
    baseline::vadv_native(&mut phi_ref, &w, dtdz, domain);

    for be in ["debug", "vector", "xla", "pjrt-aot"] {
        let stencil = match coord.stencil_for(fp, be) {
            Ok(s) => s,
            Err(e) => {
                println!(
                    "vadv {be:<10} unavailable: {}",
                    format!("{e:#}").lines().next().unwrap_or("")
                );
                continue;
            }
        };
        let (mut phi, mut wf) = make_fields(&stencil)?;
        let result = stencil
            .bind()
            .field("phi", &phi)
            .field("w", &wf)
            .scalar("dtdz", dtdz)
            .domain(domain)
            .finish()?
            .run(&mut [&mut phi, &mut wf]);
        match result {
            Ok(stats) => {
                let d = phi_ref.max_abs_diff(&phi);
                println!("vadv {be:<10} {:>12?}  max|Δ| vs native = {d:.3e}", stats.execute);
                assert!(d < 1e-10, "{be} disagrees with native solver");
            }
            Err(e) => println!(
                "vadv {be:<10} unavailable: {}",
                format!("{e:#}").lines().next().unwrap_or("")
            ),
        }
    }

    // Physical sanity: an implicit solve with positive w transports the
    // pulse upward (center of mass rises) and conserves sign.
    let center_of_mass = |s: &Storage| -> f64 {
        let [ni, nj, nk] = domain;
        let (mut num, mut den) = (0.0, 0.0);
        for i in 0..ni as i64 {
            for j in 0..nj as i64 {
                for k in 0..nk as i64 {
                    let v = s.get(i, j, k).max(0.0);
                    num += v * k as f64;
                    den += v;
                }
            }
        }
        num / den
    };
    let (phi0, _) = make_fields(&reference_stencil)?;
    let before = center_of_mass(&phi0);
    let after = center_of_mass(&phi_ref);
    println!("pulse center of mass: {before:.3} -> {after:.3} (w > 0, must rise)");
    assert!(after > before);
    println!("vertical_advection OK");
    Ok(())
}
