//! The paper's Figure 1 + Figure 3 (left) workload: horizontal diffusion.
//!
//! Runs both the Figure-1 `diffusion` stencil (externals, functions,
//! offset-composing calls) and the classic flux-limited `hdiff` benchmark
//! across every backend tier via `Stencil` handles, validating them
//! against each other and printing a mini Fig.-3 row.
//!
//!     cargo run --release --example horizontal_diffusion

use anyhow::Result;
use gt4rs::baseline;
use gt4rs::coordinator::Coordinator;
use gt4rs::storage::Storage;
use std::time::Instant;

fn fill(s: &mut Storage, seed: f64) {
    let [ni, nj, nk] = s.info.shape;
    let h = s.info.halo;
    for i in -(h[0].0 as i64)..(ni + h[0].1) as i64 {
        for j in -(h[1].0 as i64)..(nj + h[1].1) as i64 {
            for k in -(h[2].0 as i64)..(nk + h[2].1) as i64 {
                let v = ((i as f64) * 0.21 + seed).sin() * ((j as f64) * 0.17).cos()
                    + 0.05 * (k as f64);
                s.set(i, j, k, v);
            }
        }
    }
}

fn main() -> Result<()> {
    let mut coord = Coordinator::new();
    let domain = [64, 64, 32]; // an AOT artifact exists for this domain

    // --- Figure 1 stencil, with an external override ---------------------
    let mut externals = std::collections::BTreeMap::new();
    externals.insert("LIM".to_string(), 0.02);
    let fig1 = coord.stencil(gt4rs::stdlib::FIGURE1_SRC, "diffusion", "vector", &externals)?;
    println!(
        "figure-1 `diffusion`: {} temporaries, in_phi halo {}",
        fig1.ir().temporaries.len(),
        fig1.ir().field("in_phi").unwrap().extent
    );
    let mut in_phi = fig1.alloc_field("in_phi", domain)?;
    let mut out_phi = fig1.alloc_field("out_phi", domain)?;
    fill(&mut in_phi, 0.0);
    fig1.bind()
        .field("in_phi", &in_phi)
        .field("out_phi", &out_phi)
        .scalar("alpha", 0.05)
        .domain(domain)
        .finish()?
        .run(&mut [&mut in_phi, &mut out_phi])?;
    println!("figure-1 out_phi sum = {:+.9e}\n", out_phi.domain_sum());

    // --- classic hdiff across all backends -------------------------------
    let fp = coord.compile_library("hdiff")?;
    let mut results: Vec<(String, Storage, std::time::Duration)> = Vec::new();
    for be in ["debug", "vector", "xla", "pjrt-aot"] {
        let stencil = match coord.stencil_for(fp, be) {
            Ok(s) => s,
            Err(e) => {
                println!(
                    "hdiff {be:<10} unavailable: {}",
                    format!("{e:#}").lines().next().unwrap_or("")
                );
                continue;
            }
        };
        let mut inp = stencil.alloc_field("in_phi", domain)?;
        let mut coeff = stencil.alloc_field("coeff", domain)?;
        let mut out = stencil.alloc_field("out_phi", domain)?;
        fill(&mut inp, 1.0);
        coeff.fill(0.025);
        // Bind once; the first run is the compile/warmup, the second the
        // timed call (executable caches hot, shape re-check only).
        let mut inv = stencil
            .bind()
            .field("in_phi", &inp)
            .field("coeff", &coeff)
            .field("out_phi", &out)
            .domain(domain)
            .finish()?;
        match inv.run(&mut [&mut inp, &mut coeff, &mut out]) {
            Ok(_) => {
                let dt = inv.run(&mut [&mut inp, &mut coeff, &mut out])?.execute;
                println!("hdiff {be:<10} {dt:>12?}");
                results.push((be.to_string(), out, dt));
            }
            Err(e) => println!(
                "hdiff {be:<10} unavailable: {}",
                format!("{e:#}").lines().next().unwrap_or("")
            ),
        }
    }

    // hand-written native reference
    {
        let mut inp = coord.alloc_field(fp, "in_phi", domain)?;
        let mut coeff = coord.alloc_field(fp, "coeff", domain)?;
        let mut out = coord.alloc_field(fp, "out_phi", domain)?;
        fill(&mut inp, 1.0);
        coeff.fill(0.025);
        let t0 = Instant::now();
        baseline::hdiff_native(&inp, &coeff, &mut out, domain);
        println!("hdiff {:<10} {:>12?}", "native", t0.elapsed());
        results.push(("native".into(), out, t0.elapsed()));
    }

    // cross-backend agreement
    let (ref_name, ref_out, _) = &results[0];
    for (name, out, _) in &results[1..] {
        let d = ref_out.max_abs_diff(out);
        println!("  {name} vs {ref_name}: max|Δ| = {d:.3e}");
        assert!(d < 1e-9, "{name} disagrees with {ref_name}");
    }
    println!("horizontal_diffusion OK");
    Ok(())
}
