"""Pallas hdiff kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hdiff import hdiff_pallas


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float64)


@pytest.mark.parametrize(
    "domain", [(4, 4, 1), (8, 8, 4), (12, 10, 6), (5, 9, 3), (16, 16, 8)]
)
def test_hdiff_pallas_matches_ref(domain):
    ni, nj, nk = domain
    in_phi = rand((ni + 4, nj + 4, nk), seed=ni * 100 + nj)
    coeff = 0.1 + 0.01 * rand((ni, nj, nk), seed=7)
    out_p = hdiff_pallas(in_phi, coeff)
    out_r = ref.hdiff_ref(in_phi, coeff)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-13, atol=1e-13)


@settings(max_examples=25, deadline=None)
@given(
    ni=st.integers(min_value=1, max_value=12),
    nj=st.integers(min_value=1, max_value=12),
    nk=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hdiff_pallas_matches_ref_hypothesis(ni, nj, nk, seed):
    in_phi = rand((ni + 4, nj + 4, nk), seed=seed)
    coeff = rand((ni, nj, nk), seed=seed + 1) * 0.05
    out_p = hdiff_pallas(in_phi, coeff)
    out_r = ref.hdiff_ref(in_phi, coeff)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-12, atol=1e-12)


def test_hdiff_constant_field_is_fixed_point():
    # The laplacian of a constant field is zero: output == input.
    ni, nj, nk = 8, 8, 2
    in_phi = jnp.full((ni + 4, nj + 4, nk), 3.25, dtype=jnp.float64)
    coeff = jnp.full((ni, nj, nk), 0.3, dtype=jnp.float64)
    out = hdiff_pallas(in_phi, coeff)
    np.testing.assert_allclose(out, 3.25)


def test_hdiff_zero_coeff_is_identity():
    ni, nj, nk = 6, 5, 3
    in_phi = rand((ni + 4, nj + 4, nk), seed=3)
    coeff = jnp.zeros((ni, nj, nk), dtype=jnp.float64)
    out = hdiff_pallas(in_phi, coeff)
    np.testing.assert_allclose(out, in_phi[2 : ni + 2, 2 : nj + 2, :])


def test_hdiff_limiter_clips_antidiffusive_flux():
    # A linear ramp has zero laplacian; add a single spike and check the
    # flux limiter produces a bounded update (no new extrema adjacent to
    # the spike beyond the unlimited magnitude).
    ni, nj, nk = 9, 9, 1
    base = jnp.asarray(
        np.fromfunction(lambda i, j, k: 0.1 * i, (ni + 4, nj + 4, nk)),
        dtype=jnp.float64,
    )
    spike = base.at[6, 6, 0].add(10.0)
    coeff = jnp.full((ni, nj, nk), 0.1, dtype=jnp.float64)
    out = hdiff_pallas(spike, coeff)
    ref_out = ref.hdiff_ref(spike, coeff)
    np.testing.assert_allclose(out, ref_out, rtol=1e-13, atol=1e-13)
    # the spike is never amplified (the limiter zeroes anti-diffusive
    # fluxes, so at worst the extremum is untouched)
    assert out[4, 4, 0] <= spike[6, 6, 0] + 1e-12


def test_hdiff_f32_dtype_supported():
    ni, nj, nk = 6, 6, 2
    in_phi = rand((ni + 4, nj + 4, nk), seed=11).astype(jnp.float32)
    coeff = (rand((ni, nj, nk), seed=12) * 0.1).astype(jnp.float32)
    out = hdiff_pallas(in_phi, coeff)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(
        out, ref.hdiff_ref(in_phi, coeff), rtol=1e-5, atol=1e-5
    )
