"""Pallas vadv kernel vs pure-jnp oracle and the tridiagonal residual."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.vadv import vadv_pallas


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float64)


@pytest.mark.parametrize(
    "domain", [(4, 4, 2), (8, 8, 8), (12, 10, 6), (5, 9, 16), (16, 16, 8)]
)
def test_vadv_pallas_matches_ref(domain):
    ni, nj, nk = domain
    phi = rand((ni, nj, nk), seed=1)
    w = rand((ni, nj, nk), seed=2)
    out_p = vadv_pallas(phi, w, 0.3)
    out_r = ref.vadv_ref(phi, w, 0.3)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    ni=st.integers(min_value=1, max_value=10),
    nj=st.integers(min_value=1, max_value=10),
    nk=st.integers(min_value=2, max_value=12),
    dtdz=st.floats(min_value=-0.8, max_value=0.8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_vadv_pallas_matches_ref_hypothesis(ni, nj, nk, dtdz, seed):
    phi = rand((ni, nj, nk), seed=seed)
    w = rand((ni, nj, nk), seed=seed + 1)
    out_p = vadv_pallas(phi, w, dtdz)
    out_r = ref.vadv_ref(phi, w, dtdz)
    np.testing.assert_allclose(out_p, out_r, rtol=1e-11, atol=1e-11)


def test_vadv_solves_the_tridiagonal_system():
    # a_k x_{k-1} + x_k + c_k x_{k+1} = phi_k with a_0 = 0, c_last = 0.
    ni, nj, nk = 4, 3, 9
    phi = rand((ni, nj, nk), seed=5)
    w = rand((ni, nj, nk), seed=6)
    dtdz = 0.4
    x = np.asarray(vadv_pallas(phi, w, dtdz))
    phi_np = np.asarray(phi)
    w_np = np.asarray(w)
    for k in range(nk):
        a = -0.5 * dtdz * w_np[:, :, k] if k > 0 else 0.0
        c = 0.5 * dtdz * w_np[:, :, k] if k < nk - 1 else 0.0
        lhs = x[:, :, k].copy()
        if k > 0:
            lhs += a * x[:, :, k - 1]
        if k < nk - 1:
            lhs += c * x[:, :, k + 1]
        np.testing.assert_allclose(lhs, phi_np[:, :, k], rtol=1e-10, atol=1e-10)


def test_vadv_zero_wind_is_identity():
    ni, nj, nk = 6, 6, 5
    phi = rand((ni, nj, nk), seed=9)
    w = jnp.zeros((ni, nj, nk), dtype=jnp.float64)
    out = vadv_pallas(phi, w, 0.7)
    np.testing.assert_allclose(out, phi)


def test_vadv_block_sizes_equivalent():
    # The I-axis blocking is an implementation detail: results must not
    # depend on the VMEM slab size.
    ni, nj, nk = 12, 6, 7
    phi = rand((ni, nj, nk), seed=20)
    w = rand((ni, nj, nk), seed=21)
    outs = [
        vadv_pallas(phi, w, 0.25, block_i=b) for b in (1, 3, 4, 12)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-13, atol=1e-13)


def test_vadv_single_level_column():
    # nk == 1: the system degenerates to x = phi.
    phi = rand((3, 3, 1), seed=30)
    w = rand((3, 3, 1), seed=31)
    out = vadv_pallas(phi, w, 0.5)
    np.testing.assert_allclose(out, phi)
