"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These mirror the semantics of the Rust `debug` backend (the reference
interpreter) exactly, so they tie the Python and Rust halves of the test
suite to a single definition of truth:

* ``hdiff_ref`` — flux-limited horizontal diffusion (`hdiff.gts`);
* ``vadv_ref`` — implicit vertical advection / Thomas solver (`vadv.gts`);
* ``upwind_ref`` — first-order upwind horizontal advection
  (`basic.gts::upwind_advect`).

Array convention (the AOT calling convention shared with the Rust
``pjrt-aot`` backend): every field argument covers the field's *box* =
compute domain + required halo, C-order (I, J, K); outputs cover exactly
the compute domain.
"""

import jax.numpy as jnp


def hdiff_ref(in_phi, coeff):
    """Flux-limited horizontal diffusion.

    Args:
      in_phi: (ni+4, nj+4, nk) — domain plus halo 2 on I and J.
      coeff:  (ni, nj, nk).

    Returns:
      out_phi: (ni, nj, nk).
    """
    ni = in_phi.shape[0] - 4
    nj = in_phi.shape[1] - 4

    def lap(i0, j0):
        """4*phi - neighbors over a (ni+2, nj+2) region at box offset
        (i0, j0)."""
        c = in_phi[i0 : i0 + ni + 2, j0 : j0 + nj + 2, :]
        le = in_phi[i0 - 1 : i0 - 1 + ni + 2, j0 : j0 + nj + 2, :]
        r = in_phi[i0 + 1 : i0 + 1 + ni + 2, j0 : j0 + nj + 2, :]
        d = in_phi[i0 : i0 + ni + 2, j0 - 1 : j0 - 1 + nj + 2, :]
        u = in_phi[i0 : i0 + ni + 2, j0 + 1 : j0 + 1 + nj + 2, :]
        return 4.0 * c - (le + r + d + u)

    # lap over the ±1 extended region; box offset (1,1) = domain (-1,-1).
    lapf = lap(1, 1)  # (ni+2, nj+2, nk); lapf[1+di, 1+dj] = lap at (di, dj)

    # x-flux over i in [-1, ni), j in [0, nj):
    # flx(i) = lap(i+1) - lap(i), limited by sign of in(i+1) - in(i).
    flx = lapf[1 : ni + 2, 1 : nj + 1, :] - lapf[0 : ni + 1, 1 : nj + 1, :]
    dphi_x = in_phi[2 : ni + 3, 2 : nj + 2, :] - in_phi[1 : ni + 2, 2 : nj + 2, :]
    flx = jnp.where(flx * dphi_x > 0.0, 0.0, flx)  # (ni+1, nj, nk), i from -1

    # y-flux over i in [0, ni), j in [-1, nj)
    fly = lapf[1 : ni + 1, 1 : nj + 2, :] - lapf[1 : ni + 1, 0 : nj + 1, :]
    dphi_y = in_phi[2 : ni + 2, 2 : nj + 3, :] - in_phi[2 : ni + 2, 1 : nj + 2, :]
    fly = jnp.where(fly * dphi_y > 0.0, 0.0, fly)  # (ni, nj+1, nk), j from -1

    out = in_phi[2 : ni + 2, 2 : nj + 2, :] - coeff * (
        flx[1:, :, :] - flx[:-1, :, :] + fly[:, 1:, :] - fly[:, :-1, :]
    )
    return out


def vadv_ref(phi, w, dtdz):
    """Implicit vertical advection via the Thomas algorithm.

    Solves, per column, the tridiagonal system
      a_k x_{k-1} + x_k + c_k x_{k+1} = phi_k
    with a_k = -0.5*dtdz*w_k (a_0 = 0) and c_k = 0.5*dtdz*w_k (c_last = 0).

    Args:
      phi: (ni, nj, nk) current tracer.
      w:   (ni, nj, nk) vertical velocity.
      dtdz: scalar.

    Returns:
      phi_new: (ni, nj, nk).
    """
    nk = phi.shape[2]
    cp = [None] * nk
    dp = [None] * nk
    cp[0] = 0.5 * dtdz * w[:, :, 0]
    dp[0] = phi[:, :, 0]
    for k in range(1, nk):
        av = -0.5 * dtdz * w[:, :, k]
        denom = 1.0 - av * cp[k - 1]
        cp[k] = (0.5 * dtdz * w[:, :, k]) / denom
        dp[k] = (phi[:, :, k] - av * dp[k - 1]) / denom
    x = [None] * nk
    x[nk - 1] = dp[nk - 1]
    for k in range(nk - 2, -1, -1):
        x[k] = dp[k] - cp[k] * x[k + 1]
    return jnp.stack(x, axis=2)


def upwind_ref(phi, u, v, dtdx, dtdy):
    """First-order upwind horizontal advection with constant winds.

    Args:
      phi: (ni+2, nj+2, nk) — domain plus halo 1 on I and J.
      u, v, dtdx, dtdy: scalars.

    Returns:
      out: (ni, nj, nk).
    """
    ni = phi.shape[0] - 2
    nj = phi.shape[1] - 2
    c = phi[1 : ni + 1, 1 : nj + 1, :]
    dx_up = c - phi[0:ni, 1 : nj + 1, :]
    dx_dn = phi[2 : ni + 2, 1 : nj + 1, :] - c
    dy_up = c - phi[1 : ni + 1, 0:nj, :]
    dy_dn = phi[1 : ni + 1, 2 : nj + 2, :] - c
    dx = jnp.where(u > 0.0, dx_up, dx_dn)
    dy = jnp.where(v > 0.0, dy_up, dy_dn)
    return c - u * dtdx * dx - v * dtdy * dy
