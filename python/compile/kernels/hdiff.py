"""L1 Pallas kernel: flux-limited horizontal diffusion.

TPU mapping of the paper's `gtcuda` hdiff benchmark (DESIGN.md
§Hardware-Adaptation): where the CUDA backend tiles the horizontal plane
into threadblocks that stage a halo into shared memory, this kernel tiles
the vertical axis — each grid step loads one full (ni+4, nj+4) halo plane
into VMEM (a (128+4)² f64 plane is ~140 KB, far below the ~16 MB VMEM
budget), computes the whole five-stage stencil as fused VPU element-wise
arithmetic on registers/VMEM, and writes back the (ni, nj) interior.
BlockSpec index maps express the HBM→VMEM schedule; there is no MXU work
in a stencil (this kernel is memory-bound by design, matching the paper's
roofline discussion).

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so CPU artifacts are interpret-lowered while the kernel
structure remains the real TPU one (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hdiff_kernel(in_ref, coeff_ref, out_ref):
    """One vertical level: in_ref (ni+4, nj+4, 1), coeff/out (ni, nj, 1)."""
    ni = out_ref.shape[0]
    nj = out_ref.shape[1]
    phi = in_ref[...]  # (ni+4, nj+4, 1) VMEM block

    def lap(i0, j0, li, lj):
        c = phi[i0 : i0 + li, j0 : j0 + lj, :]
        le = phi[i0 - 1 : i0 - 1 + li, j0 : j0 + lj, :]
        r = phi[i0 + 1 : i0 + 1 + li, j0 : j0 + lj, :]
        d = phi[i0 : i0 + li, j0 - 1 : j0 - 1 + lj, :]
        u = phi[i0 : i0 + li, j0 + 1 : j0 + 1 + lj, :]
        return 4.0 * c - (le + r + d + u)

    lapf = lap(1, 1, ni + 2, nj + 2)  # lap over ±1, lapf[1+di, 1+dj]

    flx = lapf[1 : ni + 2, 1 : nj + 1, :] - lapf[0 : ni + 1, 1 : nj + 1, :]
    dphi_x = phi[2 : ni + 3, 2 : nj + 2, :] - phi[1 : ni + 2, 2 : nj + 2, :]
    flx = jnp.where(flx * dphi_x > 0.0, 0.0, flx)

    fly = lapf[1 : ni + 1, 1 : nj + 2, :] - lapf[1 : ni + 1, 0 : nj + 1, :]
    dphi_y = phi[2 : ni + 2, 2 : nj + 3, :] - phi[2 : ni + 2, 1 : nj + 2, :]
    fly = jnp.where(fly * dphi_y > 0.0, 0.0, fly)

    out_ref[...] = phi[2 : ni + 2, 2 : nj + 2, :] - coeff_ref[...] * (
        flx[1:, :, :] - flx[:-1, :, :] + fly[:, 1:, :] - fly[:, :-1, :]
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def hdiff_pallas(in_phi, coeff, *, interpret=True):
    """Pallas horizontal diffusion.

    Args:
      in_phi: (ni+4, nj+4, nk) f64 — domain plus halo 2.
      coeff:  (ni, nj, nk) f64.

    Returns:
      (ni, nj, nk) f64.
    """
    ni, nj, nk = coeff.shape
    grid = (nk,)
    return pl.pallas_call(
        _hdiff_kernel,
        grid=grid,
        in_specs=[
            # one full halo plane per level
            pl.BlockSpec((ni + 4, nj + 4, 1), lambda k: (0, 0, k)),
            pl.BlockSpec((ni, nj, 1), lambda k: (0, 0, k)),
        ],
        out_specs=pl.BlockSpec((ni, nj, 1), lambda k: (0, 0, k)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nk), in_phi.dtype),
        interpret=interpret,
    )(in_phi, coeff)
