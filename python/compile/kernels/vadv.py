"""L1 Pallas kernel: implicit vertical advection (Thomas solver).

TPU mapping (DESIGN.md §Hardware-Adaptation): the solve is sequential in K
and embarrassingly parallel in (I, J) — the same structure GTScript
expresses with ``computation(FORWARD)/(BACKWARD)``. The kernel keeps whole
columns resident in VMEM: the grid tiles the I axis, each program owning a
(bi, nj, nk) slab (a 8×128×128 f64 slab is ~1 MB — comfortably inside
VMEM), and runs the two sweeps as ``lax.scan`` over K on VPU lanes spanning
the horizontal block. A GPU implementation would assign columns to threads;
here the vector lanes play that role.

Lowered with ``interpret=True`` for CPU-PJRT execution (see hdiff.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vadv_kernel(phi_ref, w_ref, dtdz_ref, out_ref):
    """One I-slab: phi/w/out (bi, nj, nk); dtdz scalar (1, 1) in SMEM-ish."""
    phi = phi_ref[...]
    w = w_ref[...]
    dtdz = dtdz_ref[0, 0]

    c_coef = 0.5 * dtdz * w  # (bi, nj, nk)
    a_coef = -c_coef

    # Forward elimination, carried over K by scan.
    def fwd(carry, xs):
        cp_prev, dp_prev = carry
        a_k, c_k, d_k = xs
        denom = 1.0 - a_k * cp_prev
        cp_k = c_k / denom
        dp_k = (d_k - a_k * dp_prev) / denom
        return (cp_k, dp_k), (cp_k, dp_k)

    a_t = jnp.moveaxis(a_coef, 2, 0)  # (nk, bi, nj)
    c_t = jnp.moveaxis(c_coef, 2, 0)
    d_t = jnp.moveaxis(phi, 2, 0)

    cp0 = c_t[0]
    dp0 = d_t[0]
    (_, _), (cp_rest, dp_rest) = jax.lax.scan(
        fwd, (cp0, dp0), (a_t[1:], c_t[1:], d_t[1:])
    )
    cp = jnp.concatenate([cp0[None], cp_rest], axis=0)  # (nk, bi, nj)
    dp = jnp.concatenate([dp0[None], dp_rest], axis=0)

    # Backward substitution.
    def bwd(x_next, xs):
        cp_k, dp_k = xs
        x_k = dp_k - cp_k * x_next
        return x_k, x_k

    x_last = dp[-1]
    _, x_rest = jax.lax.scan(
        bwd, x_last, (cp[:-1], dp[:-1]), reverse=True
    )
    x = jnp.concatenate([x_rest, x_last[None]], axis=0)  # (nk, bi, nj)
    out_ref[...] = jnp.moveaxis(x, 0, 2)


@functools.partial(jax.jit, static_argnames=("interpret", "block_i"))
def vadv_pallas(phi, w, dtdz, *, interpret=True, block_i=8):
    """Pallas implicit vertical advection.

    Args:
      phi: (ni, nj, nk) f64.
      w:   (ni, nj, nk) f64.
      dtdz: scalar f64.

    Returns:
      (ni, nj, nk) f64 solved tracer.
    """
    ni, nj, nk = phi.shape
    bi = min(block_i, ni)
    while ni % bi != 0:
        bi -= 1
    grid = (ni // bi,)
    dtdz_arr = jnp.reshape(jnp.asarray(dtdz, dtype=phi.dtype), (1, 1))
    return pl.pallas_call(
        _vadv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, nj, nk), lambda i: (i, 0, 0)),
            pl.BlockSpec((bi, nj, nk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, nj, nk), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ni, nj, nk), phi.dtype),
        interpret=interpret,
    )(phi, w, dtdz_arr)
