"""AOT export: lower the L2 graphs to HLO text artifacts.

HLO *text* (never ``.serialize()``): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that the image's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Artifacts land in ``artifacts/`` named
``<stencil>[__<variant>]_<ni>x<nj>x<nk>.hlo.txt``; the default (no-suffix)
artifact is the Pallas lowering where one exists. Run via ``make
artifacts`` (a no-op when inputs are unchanged — make tracks the python
sources).

Usage: python -m compile.aot [--out-dir DIR] [--quick]
"""

import argparse
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Domain sweep of the Figure-3 benchmarks (kept in sync with
# rust/benches/fig3_*.rs) plus the small domains tests/examples use.
BENCH_DOMAINS = [
    (16, 16, 8),
    (32, 32, 16),
    (48, 48, 24),
    (64, 64, 32),
    (96, 96, 48),
    (128, 128, 64),
]
TEST_DOMAINS = [(8, 8, 4), (12, 10, 6)]
MODEL_DOMAINS = [(32, 32, 8), (48, 48, 16)]

#: (stencil, variant, emit-default-alias) — default artifact = pallas.
EXPORTS = [
    ("hdiff", "pallas", True),
    ("hdiff", "jnp", False),
    ("vadv", "pallas", True),
    ("vadv", "jnp", False),
    ("upwind_advect", "jnp", True),
    ("model_step", "pallas", True),
]

DOMAINS_BY_STENCIL = {
    "hdiff": BENCH_DOMAINS + TEST_DOMAINS + MODEL_DOMAINS,
    "vadv": BENCH_DOMAINS + TEST_DOMAINS + MODEL_DOMAINS,
    "upwind_advect": TEST_DOMAINS + MODEL_DOMAINS,
    "model_step": TEST_DOMAINS + MODEL_DOMAINS,
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_one(out_dir, stencil, variant, domain, default_alias):
    fn = model.BUILDERS[stencil](variant=variant)
    specs = model.input_specs(stencil, domain)
    # keep_unused: the AOT calling convention passes *every* field
    # (including pure outputs, which the graph ignores) — jit must not
    # prune them or the Rust side's argument count would not match.
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    ni, nj, nk = domain
    names = [f"{stencil}__{variant}_{ni}x{nj}x{nk}.hlo.txt"]
    if default_alias:
        names.append(f"{stencil}_{ni}x{nj}x{nk}.hlo.txt")
    for name in names:
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
    return len(text), names


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--quick",
        action="store_true",
        help="only the small test/model domains (fast CI path)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    total = 0
    for stencil, variant, default_alias in EXPORTS:
        domains = DOMAINS_BY_STENCIL[stencil]
        if args.quick:
            domains = [d for d in domains if d in TEST_DOMAINS + MODEL_DOMAINS]
        for domain in domains:
            n, names = export_one(args.out_dir, stencil, variant, domain, default_alias)
            total += 1
            print(f"  wrote {names[-1]} ({n} chars)", file=sys.stderr)
    print(f"exported {total} artifacts to {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
