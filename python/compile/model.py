"""L2: the JAX compute graphs exported as AOT artifacts.

Each builder returns a function with the AOT calling convention shared with
the Rust ``pjrt-aot`` backend (see `rust/src/backend/pjrt_aot.rs`):

* one f64 input per stencil field, shaped to the field's *box* (compute
  domain + required halo, C-order I,J,K) — including output fields, whose
  incoming values are the storage's current contents;
* one rank-0 f64 input per scalar parameter;
* returns a tuple with one (ni, nj, nk) array per *written* field, in
  declaration order.

Two lowering variants exist per kernel: ``pallas`` (the L1 kernels, the
default artifact, the paper's `gtcuda` analog) and ``jnp`` (plain jnp, the
ablation variant).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import ref  # noqa: E402
from .kernels.hdiff import hdiff_pallas  # noqa: E402
from .kernels.vadv import vadv_pallas  # noqa: E402


def build_hdiff(variant="pallas"):
    """hdiff(in_phi box(+2,+2,0), coeff box(0), out_phi box(0)) -> (out,)."""

    def fn(in_phi, coeff, out_phi):
        del out_phi  # fully overwritten
        if variant == "pallas":
            out = hdiff_pallas(in_phi, coeff)
        else:
            out = ref.hdiff_ref(in_phi, coeff)
        return (out,)

    return fn


def build_vadv(variant="pallas"):
    """vadv(phi box(0), w box(0); dtdz) -> (phi_new,)."""

    def fn(phi, w, dtdz):
        if variant == "pallas":
            out = vadv_pallas(phi, w, dtdz)
        else:
            out = ref.vadv_ref(phi, w, dtdz)
        return (out,)

    return fn


def build_upwind_advect(variant="jnp"):
    """upwind_advect(phi box(+1,+1,0), out box(0); u, v, dtdx, dtdy)."""
    del variant

    def fn(phi, out, u, v, dtdx, dtdy):
        del out
        return (ref.upwind_ref(phi, u, v, dtdx, dtdy),)

    return fn


def build_model_step(variant="pallas"):
    """One fused L2 model macro-step: hdiff then vadv on the tracer.

    Demonstrates L2 composition of L1 kernels in a single XLA program
    (inputs: phi box(+2,+2,0), coeff box(0), w box(0); scalar dtdz).
    Returns the updated (ni, nj, nk) tracer.
    """

    def fn(phi_box, coeff, w, dtdz):
        if variant == "pallas":
            diffused = hdiff_pallas(phi_box, coeff)
            out = vadv_pallas(diffused, w, dtdz)
        else:
            diffused = ref.hdiff_ref(phi_box, coeff)
            out = ref.vadv_ref(diffused, w, dtdz)
        return (out,)

    return fn


#: stencil name -> (builder, input spec builder)
def input_specs(name, domain):
    """ShapeDtypeStructs for a stencil's AOT inputs at `domain`."""
    ni, nj, nk = domain
    f64 = jnp.float64
    box = lambda hi, hj: jax.ShapeDtypeStruct((ni + hi, nj + hj, nk), f64)
    scalar = jax.ShapeDtypeStruct((), f64)
    if name == "hdiff":
        return [box(4, 4), box(0, 0), box(0, 0)]
    if name == "vadv":
        return [box(0, 0), box(0, 0), scalar]
    if name == "upwind_advect":
        return [box(2, 2), box(0, 0), scalar, scalar, scalar, scalar]
    if name == "model_step":
        return [box(4, 4), box(0, 0), box(0, 0), scalar]
    raise KeyError(f"unknown AOT stencil {name!r}")


BUILDERS = {
    "hdiff": build_hdiff,
    "vadv": build_vadv,
    "upwind_advect": build_upwind_advect,
    "model_step": build_model_step,
}
